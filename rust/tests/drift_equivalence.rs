//! Drift-aware serving equivalence (the "Drift, faults, and refresh
//! epochs" contract in `coordinator::engine`):
//!
//! * At age 0 with fault injection disabled, the drift-aware engine is
//!   **byte-identical** to the pre-drift serving path — same pairs, same
//!   ops, same energy as the one-shot `SearchPipeline` — and
//!   `advance_age(0.0)` is a strict no-op.
//! * At any fixed (age, fault seed, refresh schedule) state, scores and
//!   `OpCounts` are bit-identical across MVM backends and across 1/2/3
//!   shard counts: drift uses per-row logical clocks, fault draws
//!   interleave deterministically in the chained programming-noise
//!   stream, and refresh draws come from per-(global row, epoch) roots,
//!   so no partitioning choice can leak into results.

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{RefreshPolicy, SearchEngine, SearchPipeline, ShardedSearchEngine};
use specpcm::device::FaultModel;
use specpcm::ms::{SearchDataset, Spectrum};

fn cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    }
}

/// The same config with mild fault injection enabled.
fn faulty_cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        fault: FaultModel::new(0.003, 0.002, 2.0),
        ..cfg()
    }
}

#[test]
fn age_zero_faults_off_is_byte_identical_to_pre_drift_serving() {
    let ds = SearchDataset::generate("t", 11, 60, 80, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();

    let one_shot = SearchPipeline::new(cfg()).run(&ds, &be).unwrap();
    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let batch = engine.search_batch(&queries, &be).unwrap();
    let out = engine.finalize(&queries, std::slice::from_ref(&batch)).unwrap();
    assert_eq!(out.pairs, one_shot.pairs);
    assert_eq!(out.fdr.accepted, one_shot.fdr.accepted);
    assert_eq!(out.ops, one_shot.ops);
    assert_eq!(out.report.total_j(), one_shot.report.total_j());

    // The health snapshot confirms a fresh, fault-free device.
    assert_eq!(batch.health.max_age_seconds, 0.0);
    assert_eq!(batch.health.injected_faults, 0);
    assert_eq!(batch.health.refreshes, 0);

    // advance_age(0.0) must not perturb a single bit.
    engine.advance_age(0.0);
    let again = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(again.pairs, batch.pairs);
    assert_eq!(again.matched, batch.matched);
    assert_eq!(again.ops, batch.ops);
}

#[test]
fn aged_faulted_state_is_identical_across_backends() {
    let ds = SearchDataset::generate("t", 17, 60, 50, 0.8, 0.2, 0, 0);
    let run = |be: &BackendDispatcher| {
        let mut engine = SearchEngine::program(faulty_cfg(), &ds, be).unwrap();
        engine.advance_age(3.0e8);
        let queries: Vec<&Spectrum> = ds.queries.iter().collect();
        let batch = engine.search_batch(&queries, be).unwrap();
        (batch, engine.device_health())
    };
    let (ref_batch, ref_health) = run(&BackendDispatcher::reference());
    let (par_batch, par_health) = run(&BackendDispatcher::parallel(4));
    assert_eq!(ref_batch.pairs, par_batch.pairs);
    assert_eq!(ref_batch.matched, par_batch.matched);
    assert_eq!(ref_batch.ops, par_batch.ops);
    assert_eq!(ref_health, par_health);
    // The workload actually exercised injection and aging.
    assert!(ref_health.injected_faults > 0, "fault rates too low to fire");
    assert_eq!(ref_health.max_age_seconds, 3.0e8);
}

/// 36 banks at D=2048 n=3 (6 segments) = 6 bank groups x 128 = 768 slots.
const UNION_BANKS: usize = 36;

#[test]
fn aged_faulted_refresh_schedule_is_identical_across_shard_counts() {
    // 120 targets + 120 decoys, served through a drift/refresh schedule:
    // age, budgeted partial refresh, age again, serve. Every step must be
    // bit-identical between one monolithic engine owning the union pool
    // and k shards of 36/k banks each.
    let ds = SearchDataset::generate("t", 11, 120, 60, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let partial = RefreshPolicy {
        max_age_seconds: 1.0,
        budget: 5,
    };
    let full = RefreshPolicy {
        max_age_seconds: 0.0,
        budget: 0,
    };

    // Monolithic oracle, driven through the shard layer with one shard so
    // both sides run the exact same schedule code path.
    let mono_cfg = SpecPcmConfig {
        num_banks: UNION_BANKS,
        ..faulty_cfg()
    };
    let mut mono = ShardedSearchEngine::program(mono_cfg, &ds, &be, 1).unwrap();
    let mono_initial_ops = *mono.program_ops();
    let mono_initial_health = mono.device_health();
    mono.advance_age(2.0e8);
    let mono_partial = mono.maintain(&partial);
    mono.advance_age(5.0e8);
    let mono_batch = mono.search_batch(&queries, &be).unwrap();
    let mono_full = mono.maintain(&full);
    let mono_after = mono.search_batch(&queries, &be).unwrap();
    let mono_out = mono
        .finalize(&queries, &[mono_batch.clone(), mono_after.clone()])
        .unwrap();
    assert!(mono_partial.rows > 0 && mono_full.rows > 0);

    for shards in [2usize, 3] {
        let shard_cfg = SpecPcmConfig {
            num_banks: UNION_BANKS / shards,
            ..faulty_cfg()
        };
        let mut engine = ShardedSearchEngine::program(shard_cfg, &ds, &be, shards).unwrap();
        assert_eq!(engine.n_shards(), shards);
        // Chained noise + interleaved fault draws: one-time programming
        // (including which cells faulted) matches the monolithic engine.
        assert_eq!(*engine.program_ops(), mono_initial_ops, "{shards} shards");
        assert_eq!(engine.device_health(), mono_initial_health, "{shards} shards");

        engine.advance_age(2.0e8);
        let p = engine.maintain(&partial);
        // Global selection: the same rows refresh no matter the partition
        // (bucket segment counts may differ at shard boundaries).
        assert_eq!(p.rows, mono_partial.rows, "{shards} shards");
        assert_eq!(p.ops, mono_partial.ops, "{shards} shards");

        engine.advance_age(5.0e8);
        let batch = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(batch.pairs, mono_batch.pairs, "{shards} shards");
        assert_eq!(batch.matched, mono_batch.matched, "{shards} shards");
        assert_eq!(batch.ops, mono_batch.ops, "{shards} shards");
        assert_eq!(batch.report.total_j(), mono_batch.report.total_j());
        assert_eq!(batch.health, mono_batch.health, "{shards} shards");

        let f = engine.maintain(&full);
        assert_eq!(f.rows, mono_full.rows, "{shards} shards");
        assert_eq!(f.ops, mono_full.ops, "{shards} shards");

        let after = engine.search_batch(&queries, &be).unwrap();
        assert_eq!(after.pairs, mono_after.pairs, "{shards} shards");
        assert_eq!(after.health, mono_after.health, "{shards} shards");

        let out = engine
            .finalize(&queries, &[batch.clone(), after.clone()])
            .unwrap();
        assert_eq!(out.pairs, mono_out.pairs, "{shards} shards");
        assert_eq!(out.fdr.accepted, mono_out.fdr.accepted);
        assert_eq!(out.identified, mono_out.identified);
        assert_eq!(out.correct, mono_out.correct);
        assert_eq!(out.ops, mono_out.ops, "{shards} shards");
        assert_eq!(out.report.total_j(), mono_out.report.total_j());
        assert_eq!(engine.program_ops(), mono.program_ops(), "{shards} shards");
    }
}

#[test]
fn refresh_resets_staleness_without_touching_marginal_accounting() {
    let ds = SearchDataset::generate("t", 19, 60, 40, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let mut engine = SearchEngine::program(faulty_cfg(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    engine.advance_age(1.0e9);
    let stale = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(stale.health.max_age_seconds, 1.0e9);
    assert!(stale.health.est_conductance_loss > 0.0);

    let one_time_before = engine.program_ops().program_rounds;
    let out = engine.maintain(&RefreshPolicy {
        max_age_seconds: 0.0,
        budget: 0,
    });
    assert_eq!(out.rows, engine.n_refs());

    let fresh = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(fresh.health.max_age_seconds, 0.0);
    assert_eq!(fresh.health.est_conductance_loss, 0.0);
    assert_eq!(fresh.health.refreshes, engine.n_refs() as u64);
    // Refresh work lands on the one-time ledger; batches stay marginal.
    assert!(engine.program_ops().program_rounds > one_time_before);
    assert_eq!(fresh.ops.program_rounds, 0);
    assert_eq!(fresh.ops.verify_rounds, 0);
    // Same queries, same candidate sets: marginal work is unchanged by
    // aging or refreshing — only scores move.
    assert_eq!(fresh.ops, stale.ops);
}

#[test]
fn live_mutation_keeps_serving_and_age_zero_identity() {
    // Remove + re-add on a programmed engine, then check the engine still
    // serves every query and that an untouched twin remains byte-identical
    // to the pre-drift path (the mutation machinery must not perturb the
    // default-constructed serving state).
    let ds = SearchDataset::generate("t", 23, 60, 30, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let baseline = engine.search_batch(&queries, &be).unwrap();

    engine.remove_references(&[2, 3, 61]).unwrap();
    assert_eq!(engine.n_refs(), 117);
    let extra = SearchDataset::generate("x", 29, 6, 1, 0.8, 0.2, 0, 0);
    let add: Vec<&Spectrum> = extra.library.iter().take(3).collect();
    let rows = engine.add_references(&add, true, &be).unwrap();
    assert_eq!(rows, vec![120, 121, 122]);
    assert_eq!(engine.n_refs(), 120);
    engine.advance_age(1.0e6);
    let mutated = engine.search_batch(&queries, &be).unwrap();
    assert_eq!(mutated.pairs.len(), queries.len());
    assert!(mutated.health.max_age_seconds >= 1.0e6 - 1.0);

    // An identically-programmed engine that never mutated still matches
    // the baseline bit for bit.
    let twin = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let twin_batch = twin.search_batch(&queries, &be).unwrap();
    assert_eq!(twin_batch.pairs, baseline.pairs);
    assert_eq!(twin_batch.matched, baseline.matched);
    assert_eq!(twin_batch.ops, baseline.ops);
}
