//! Property-based tests (hand-rolled generator loops over `util::Rng`; the
//! proptest crate is unavailable offline). Each property runs a few hundred
//! randomized cases with printable counterexamples on failure.
//!
//! Invariants covered (DESIGN.md §8):
//! * ISA encode/decode round-trips for every valid field combination.
//! * Dimension packing: length law, bound law, adjacent-sum law, and
//!   unbiasedness of the packed dot product.
//! * Allocator never double-books and frees restore capacity.
//! * Batcher covers every index exactly once, in order.
//! * Complete-linkage merge distances are monotone non-decreasing and the
//!   cut at +inf yields one cluster per connected component.
//! * ADC transfer: idempotent on its own output codes, odd symmetry.
//! * FDR: achieved FDR never exceeds the requested rate.
//! * ShardPlan: partitions are disjoint, exhaustive, order-preserving and
//!   balanced; auto plans fit per-engine capacity with a minimal count.

use specpcm::array::AdcConfig;
use specpcm::cluster::complete_linkage;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{Batcher, SegmentAllocator, ShardPlan};
use specpcm::hd;
use specpcm::isa::{decode, encode, Instruction};
use specpcm::search::fdr_filter;
use specpcm::util::Rng;

const CASES: usize = 300;

#[test]
fn prop_isa_roundtrip() {
    let mut rng = Rng::new(0x15a);
    for case in 0..CASES {
        let inst = match rng.below(3) {
            0 => Instruction::StoreHv {
                buf: rng.below(256) as u8,
                arr_idx: rng.below(65536) as u16,
                col_addr: rng.below(256) as u8,
                row_addr: rng.below(256) as u8,
                mlc_bits: 1 + rng.below(4) as u8,
                write_cycles: rng.below(16) as u8,
            },
            1 => Instruction::ReadHv {
                buf: rng.below(256) as u8,
                data_size: rng.below(65536) as u16,
                arr_idx: rng.below(65536) as u16,
                col_addr: rng.below(256) as u8,
                row_addr: rng.below(256) as u8,
                mlc_bits: 1 + rng.below(4) as u8,
            },
            _ => Instruction::MvmCompute {
                buf: rng.below(256) as u8,
                arr_idx: rng.below(65536) as u16,
                row_addr: rng.below(256) as u8,
                num_activated_row: 1 + rng.below(128) as u8,
                adc_bits: 1 + rng.below(6) as u8,
                mlc_bits: 1 + rng.below(4) as u8,
            },
        };
        let back = decode(encode(&inst)).unwrap();
        assert_eq!(back, inst, "case {case}");
    }
}

#[test]
fn prop_packing_laws() {
    let mut rng = Rng::new(0x9ac);
    for case in 0..CASES {
        let d = 1 + rng.below(4096);
        let n = 1 + rng.below(4);
        let hv: hd::Hv = (0..d).map(|_| rng.pm1()).collect();
        let p = hd::pack(&hv, n);

        // Length law: padded to a 128 multiple of ceil(d/n).
        assert_eq!(p.len(), hd::padded_packed_len(d, n), "case {case} d={d} n={n}");
        assert_eq!(p.len() % 128, 0);
        // Bound law.
        assert!(p.iter().all(|v| v.abs() <= n as f32));
        // Adjacent-sum law on a random group.
        let groups = d.div_ceil(n);
        let g = rng.below(groups);
        let lo = g * n;
        let hi = (lo + n).min(d);
        let manual: i32 = hv[lo..hi].iter().map(|&x| x as i32).sum();
        assert_eq!(p[g], manual as f32, "case {case} group {g}");
        // Padding is zero.
        assert!(p[groups..].iter().all(|&v| v == 0.0));
    }
}

#[test]
fn prop_allocator_never_double_books() {
    let mut rng = Rng::new(0xa110c);
    for case in 0..60 {
        let segments = 1 + rng.below(6);
        let groups = 1 + rng.below(4);
        let mut alloc = SegmentAllocator::new(segments * groups, segments * 128);
        let mut live = std::collections::HashSet::new();

        for _ in 0..2000 {
            if rng.uniform() < 0.6 {
                if let Some(slot) = alloc.alloc() {
                    assert!(live.insert(slot), "case {case}: double-booked {slot:?}");
                }
            } else if !live.is_empty() {
                let slot = *live.iter().next().unwrap();
                live.remove(&slot);
                alloc.release(slot);
            }
        }
        assert_eq!(alloc.free_slots() + live.len(), alloc.capacity(), "case {case}");
    }
}

#[test]
fn prop_batcher_covers_exactly_once_in_order() {
    let mut rng = Rng::new(0xba7c);
    for case in 0..CASES {
        let total = rng.below(5000);
        let chunk = 1 + rng.below(1500);
        let batches = Batcher::new(total, chunk).batches();
        let mut next = 0usize;
        for b in &batches {
            assert_eq!(b.start, next, "case {case}: gap or overlap");
            assert!(b.len() <= chunk && !b.is_empty());
            next = b.end;
        }
        assert_eq!(next, total, "case {case}: tail not covered");
    }
}

#[test]
fn prop_linkage_monotone_and_connected_components() {
    let mut rng = Rng::new(0x111c);
    for case in 0..80 {
        let n = 2 + rng.below(40);
        // Random symmetric distance matrix.
        let mut d = vec![0f32; n * n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = rng.uniform() as f32;
                d[i * n + j] = v;
                d[j * n + i] = v;
            }
        }
        let dend = complete_linkage(&d, n, f32::INFINITY);
        assert_eq!(dend.merges.len(), n - 1, "case {case}: full dendrogram");
        for w in dend.merges.windows(2) {
            assert!(
                w[0].distance <= w[1].distance,
                "case {case}: merge distances decreased"
            );
        }
        // Cutting at +inf gives a single cluster.
        let labels = dend.cut(f32::INFINITY);
        assert!(labels.iter().all(|&l| l == labels[0]), "case {case}");
        // Cutting below the smallest distance gives all singletons.
        let min_d = dend.merges[0].distance;
        let labels = dend.cut(min_d * 0.5);
        let uniq: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(uniq.len(), n, "case {case}");
    }
}

#[test]
fn prop_adc_idempotent_and_odd() {
    let mut rng = Rng::new(0xadc);
    for case in 0..CASES {
        let bits = 1 + rng.below(6) as u32;
        let clip = 2f32.powi(5 + rng.below(6) as i32);
        let adc = AdcConfig::new(bits, clip);
        let x = (rng.uniform() as f32 - 0.5) * 4.0 * clip;
        let y = adc.quantize(x);
        // Idempotence: quantizing an output code is a fixed point.
        assert_eq!(adc.quantize(y), y, "case {case} bits={bits} x={x}");
        // Odd symmetry away from the asymmetric min code.
        if y.abs() < adc.qmax() * adc.lsb() {
            assert_eq!(adc.quantize(-x), -y, "case {case} x={x}");
        }
    }
}

#[test]
fn prop_fdr_never_exceeds_requested() {
    let mut rng = Rng::new(0xfd);
    for case in 0..100 {
        let n = 50 + rng.below(500);
        // Mixed-quality pairs.
        let pairs: Vec<(f32, f32)> = (0..n)
            .map(|_| {
                let good = rng.uniform() < 0.6;
                let t = if good {
                    5.0 + rng.gaussian() as f32
                } else {
                    rng.gaussian() as f32
                };
                let d = rng.gaussian() as f32;
                (t, d)
            })
            .collect();
        let fdr = [0.01, 0.05, 0.1][rng.below(3)];
        let r = fdr_filter(&pairs, fdr);
        assert!(r.achieved_fdr <= fdr + 1e-9, "case {case}: {}", r.achieved_fdr);
        // All accepted beat the threshold and their own decoy.
        for &i in &r.accepted {
            assert!(pairs[i].0 >= r.threshold && pairs[i].0 > pairs[i].1);
        }
    }
}

#[test]
fn prop_shard_plan_disjoint_exhaustive_order_preserving() {
    let mut rng = Rng::new(0x5a4d);
    for case in 0..CASES {
        let t = rng.below(500);
        let d = rng.below(500);
        let k = 1 + rng.below(12);
        let p = ShardPlan::balanced(t, d, k);
        let rows = t + d;

        // Exhaustive + disjoint + order-preserving: the ranges tile
        // [0, rows) exactly, in ascending order.
        let mut cursor = 0;
        for i in 0..p.n_shards() {
            let r = p.range(i);
            assert_eq!(r.start, cursor, "case {case}: gap/overlap at shard {i}");
            assert!(r.end >= r.start, "case {case}");
            cursor = r.end;

            // The target/decoy subranges re-compose the global range and
            // never cross the boundary.
            let tr = p.target_range(i);
            let dr = p.decoy_range(i);
            assert_eq!(tr.len() + dr.len(), r.len(), "case {case} shard {i}");
            assert!(tr.end <= t && dr.end <= d, "case {case} shard {i}");
            if !tr.is_empty() {
                assert_eq!(tr.start, r.start, "case {case} shard {i}");
            }
            if !dr.is_empty() {
                assert_eq!(t + dr.end, r.end, "case {case} shard {i}");
            }
        }
        assert_eq!(cursor, rows, "case {case}: ranges must cover every row");

        // Balanced: shard sizes differ by at most one, larger shards first.
        let sizes: Vec<usize> = p.ranges().iter().map(|r| r.len()).collect();
        let mn = *sizes.iter().min().unwrap();
        let mx = *sizes.iter().max().unwrap();
        assert!(mx - mn <= 1, "case {case}: sizes {sizes:?}");
        assert!(
            sizes.windows(2).all(|w| w[0] >= w[1]),
            "case {case}: remainder must go to earlier shards: {sizes:?}"
        );
    }
}

#[test]
fn prop_shard_plan_auto_fits_capacity_minimally() {
    let mut rng = Rng::new(0xca9);
    for case in 0..CASES {
        let t = rng.below(900);
        let d = rng.below(900);
        // D=2048 n=3 packs to 6 segments; banks a multiple of that keeps
        // the per-engine capacity math exact: (banks/6) * 128 slots.
        let banks = 6 * (1 + rng.below(8));
        let cfg = SpecPcmConfig {
            hd_dim: 2048,
            num_banks: banks,
            ..SpecPcmConfig::paper_search()
        };
        let capacity = (banks / 6) * 128;
        let p = ShardPlan::for_capacity(&cfg, t, d, 0).unwrap();

        // Every shard fits one engine...
        assert!(
            p.ranges().iter().all(|r| r.len() <= capacity),
            "case {case}: banks={banks} t={t} d={d} ranges={:?}",
            p.ranges()
        );
        // ...and the shard count is minimal: one fewer could not hold
        // every row (vacuous for the degenerate empty-library plan).
        if t + d > 0 {
            assert!(
                (t + d) > (p.n_shards() - 1) * capacity,
                "case {case}: {} shards not minimal for {} rows @ {capacity}",
                p.n_shards(),
                t + d
            );
        }
    }
}
