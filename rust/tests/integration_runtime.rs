//! Integration: AOT artifacts loaded through PJRT agree **bit-exactly**
//! with the rust reference implementations (DESIGN.md §8).
//!
//! Feature-gated: the whole file needs `--features pjrt` (plus a vendored
//! `xla` crate). The tests additionally skip gracefully when `artifacts/`
//! has not been built; run `make artifacts` first for full coverage. The
//! exactness argument (pow-2 ADC full-scale keeps the whole pipeline in
//! exactly-representable f32) is laid out in python/tests/test_imc_mvm.py.
#![cfg(feature = "pjrt")]

use specpcm::array::{imc_mvm_ref, AdcConfig};
use specpcm::hd::{self, ItemMemory};
use specpcm::runtime::{Manifest, Runtime};
use specpcm::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime loads"))
}

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

#[test]
fn pjrt_platform_is_cpu() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform());
}

#[test]
fn mvm_artifact_matches_rust_reference_bit_exactly() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, r) = (rt.manifest.batch, rt.manifest.rows);
    let mut rng = Rng::new(0xA11CE);

    for &c in &[768usize, 2816] {
        let q = rand_packed(&mut rng, b * c, 3);
        let g = rand_packed(&mut rng, r * c, 3);
        let adc = AdcConfig::new(6, 512.0);
        let got = rt.mvm(c, &q, &g, adc.lsb(), adc.qmax()).expect("mvm runs");
        let want = imc_mvm_ref(&q, &g, b, r, c, adc);
        assert_eq!(got.len(), want.len());
        let diff = got.iter().zip(&want).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 0, "c={c}: {diff} mismatching scores");
    }
}

#[test]
fn mvm_artifact_adc_scalars_are_runtime_knobs() {
    // One artifact serves every ADC_bits setting via the scalar inputs —
    // the ISA's ADC_bits field with no recompilation (§III-D).
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, r, c) = (rt.manifest.batch, rt.manifest.rows, 768usize);
    let mut rng = Rng::new(7);
    let q = rand_packed(&mut rng, b * c, 3);
    let g = rand_packed(&mut rng, r * c, 3);

    for bits in 1..=6u32 {
        let adc = AdcConfig::default_for_packing(bits, 3);
        let got = rt.mvm(c, &q, &g, adc.lsb(), adc.qmax()).unwrap();
        let want = imc_mvm_ref(&q, &g, b, r, c, adc);
        assert_eq!(got, want, "adc_bits={bits}");
    }
}

#[test]
fn encoder_artifact_matches_rust_hd_bit_exactly() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, f, m) = (rt.manifest.batch, rt.manifest.features, rt.manifest.levels);
    let (d, n) = (2048usize, 3usize);
    assert!(rt.manifest.get(&Manifest::enc_pack_name(d, n)).is_some());

    let im = ItemMemory::generate(42, f, m, d);
    let mut rng = Rng::new(43);
    // Sparse levels like real preprocessed spectra.
    let mut levels = vec![0i32; b * f];
    let mut levels_u16 = vec![vec![0u16; f]; b];
    for bi in 0..b {
        for _ in 0..100 {
            let pos = rng.below(f);
            let lvl = 1 + rng.below(m - 1);
            levels[bi * f + pos] = lvl as i32;
            levels_u16[bi][pos] = lvl as u16;
        }
    }

    let got = rt
        .encode_pack(d, n, &levels, &im.id_hvs_f32(), &im.level_hvs_f32())
        .expect("encoder runs");

    let cp = hd::padded_packed_len(d, n);
    assert_eq!(got.len(), b * cp);
    for bi in 0..b {
        let hv = hd::encode(&levels_u16[bi], &im);
        let want = hd::pack(&hv, n);
        assert_eq!(&got[bi * cp..(bi + 1) * cp], &want[..], "spectrum {bi}");
    }
}

#[test]
fn executable_cache_compiles_once() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (b, r, c) = (rt.manifest.batch, rt.manifest.rows, 768usize);
    let q = vec![0f32; b * c];
    let g = vec![0f32; r * c];
    rt.mvm(c, &q, &g, 16.0, 31.0).unwrap();
    rt.mvm(c, &q, &g, 16.0, 31.0).unwrap();
    assert_eq!(rt.exec_counts[&Manifest::mvm_name(c)], 2);
    assert_eq!(rt.total_execs(), 2);
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let err = rt.mvm(999, &[0.0; 64 * 999], &[0.0; 1024 * 999], 1.0, 1.0);
    assert!(err.is_err());
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("mvm_c999"), "{msg}");
}
