//! Front-door scheduling equivalence (the "Serving front door" contract
//! in `coordinator::scheduler`):
//!
//! For **any** arrival trace, **any** coalescing policy, **any** MVM
//! backend and **any** shard count, the front door's arrival-order
//! fan-back (per-query `(target, decoy)` pairs and matched peptides)
//! and its cumulative marginal `OpCounts` are **bit-identical** to one
//! `search_batch` over the same spectra in arrival order. Coalescing is
//! a host-side scheduling choice, exactly like backend or shard
//! selection — it can change wall time and telemetry, never results or
//! simulated ASIC cost.
//!
//! Refresh-in-gaps composes with the invariant: maintain increments
//! charge the one-time ledger, so batch ops stay oracle-identical even
//! while idle gaps re-program stale rows on an aged engine (and on a
//! fresh engine, the age-0 threshold makes maintain select nothing, so
//! scores are untouched too).

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{
    tile_fill_target, ArrivalTrace, CoalescePolicy, FrontDoor, RefreshPolicy, SearchEngine,
    ServeTraceOutcome, ShardedSearchEngine,
};
use specpcm::energy::OpCounts;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::util::Rng;

fn cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    }
}

/// The policy grid every test sweeps: naive, size-triggered at two fill
/// targets (one matching the config-default utilization floor), and
/// size+deadline with a tight bound.
fn policies() -> Vec<CoalescePolicy> {
    vec![
        CoalescePolicy::Off,
        CoalescePolicy::Size { max_batch: 7 },
        CoalescePolicy::Size {
            max_batch: tile_fill_target(0.3),
        },
        CoalescePolicy::SizeDeadline {
            max_batch: 16,
            deadline_ticks: 5,
        },
    ]
}

/// The trace grid: Poisson at two intensities, an all-at-once burst,
/// and a sparse trickle (deadline/drain heavy).
fn traces(n: usize) -> Vec<(&'static str, ArrivalTrace)> {
    let mut rng = Rng::new(0x7ace);
    vec![
        ("poisson-1", ArrivalTrace::poisson_from_rng(&mut rng, n, 1.0)),
        ("poisson-7", ArrivalTrace::poisson_from_rng(&mut rng, n, 7.0)),
        ("burst", ArrivalTrace::uniform(n, 0)),
        ("trickle", ArrivalTrace::uniform(n, 50)),
    ]
}

fn assert_matches_oracle(
    served: &ServeTraceOutcome,
    oracle_pairs: &[(f32, f32)],
    oracle_matched: &[Option<u32>],
    oracle_ops: &OpCounts,
    tag: &str,
) {
    assert_eq!(served.pairs, oracle_pairs, "{tag}: pairs diverged");
    assert_eq!(served.matched, oracle_matched, "{tag}: matches diverged");
    assert_eq!(&served.ops, oracle_ops, "{tag}: marginal ops diverged");
    // The per-field fold sanity: outcome concatenation == fan-back.
    let concat: Vec<(f32, f32)> = served
        .outcomes
        .iter()
        .flat_map(|o| o.pairs.iter().copied())
        .collect();
    assert_eq!(concat, served.pairs, "{tag}: fan-back is not FIFO");
    assert_eq!(served.stats.requests as usize, served.pairs.len(), "{tag}");
    assert_eq!(served.stats.batches as usize, served.outcomes.len(), "{tag}");
}

#[test]
fn every_policy_and_trace_matches_the_arrival_order_oracle() {
    let ds = SearchDataset::generate("fd", 31, 60, 48, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    for be in [BackendDispatcher::reference(), BackendDispatcher::parallel(4)] {
        let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
        let oracle = engine.search_batch(&queries, &be).unwrap();
        for (tname, trace) in traces(queries.len()) {
            for policy in policies() {
                let tag = format!("{}/{tname}/{}", be.primary_name(), policy.name());
                let fd = FrontDoor::new(policy);
                let served = fd.serve_trace(&mut engine, &queries, &trace, &be).unwrap();
                assert_matches_oracle(
                    &served,
                    &oracle.pairs,
                    &oracle.matched,
                    &oracle.ops,
                    &tag,
                );
                if policy == CoalescePolicy::Off {
                    // Naive serving really is one batch per request.
                    assert_eq!(served.outcomes.len(), queries.len(), "{tag}");
                    assert_eq!(served.stats.max_queue_depth, 1, "{tag}");
                }
            }
        }
    }
}

/// 36 banks at D=2048 n=3 (6 segments) = 6 bank groups x 128 = 768 slots.
const UNION_BANKS: usize = 36;

#[test]
fn sharded_front_door_matches_the_monolithic_oracle() {
    let ds = SearchDataset::generate("fd", 37, 120, 40, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    // Monolithic oracle over the union pool, one arrival-order batch.
    let mono_cfg = SpecPcmConfig {
        num_banks: UNION_BANKS,
        ..cfg()
    };
    let mono = ShardedSearchEngine::program(mono_cfg, &ds, &be, 1).unwrap();
    let oracle = mono.search_batch(&queries, &be).unwrap();

    for shards in [1usize, 2, 3] {
        let shard_cfg = SpecPcmConfig {
            num_banks: UNION_BANKS / shards,
            ..cfg()
        };
        let mut engine = ShardedSearchEngine::program(shard_cfg, &ds, &be, shards).unwrap();
        assert_eq!(engine.n_shards(), shards);
        for (tname, trace) in traces(queries.len()) {
            for policy in policies() {
                let tag = format!("{shards}-shard/{tname}/{}", policy.name());
                let fd = FrontDoor::new(policy);
                let served = fd.serve_trace(&mut engine, &queries, &trace, &be).unwrap();
                assert_matches_oracle(
                    &served,
                    &oracle.pairs,
                    &oracle.matched,
                    &oracle.ops,
                    &tag,
                );
            }
        }
    }
}

#[test]
fn refresh_in_gaps_is_result_neutral_on_a_fresh_engine() {
    // At age 0 every candidate fails `age > max_age`, so maintain selects
    // nothing — but the code path runs in every idle gap, and serving
    // stays bit-identical to a front door with no refresh policy at all.
    let ds = SearchDataset::generate("fd", 41, 60, 32, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();
    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let oracle = engine.search_batch(&queries, &be).unwrap();

    let trace = ArrivalTrace::uniform(queries.len(), 50); // gap-heavy
    let policy = CoalescePolicy::SizeDeadline {
        max_batch: 8,
        deadline_ticks: 5,
    };
    let plain = FrontDoor::new(policy)
        .serve_trace(&mut engine, &queries, &trace, &be)
        .unwrap();
    let refreshing = FrontDoor::new(policy)
        .with_refresh(RefreshPolicy {
            max_age_seconds: 1.0,
            budget: 2,
        })
        .serve_trace(&mut engine, &queries, &trace, &be)
        .unwrap();

    assert_matches_oracle(&plain, &oracle.pairs, &oracle.matched, &oracle.ops, "plain");
    assert_matches_oracle(
        &refreshing,
        &oracle.pairs,
        &oracle.matched,
        &oracle.ops,
        "refreshing",
    );
    // The gaps really ran maintain — it just had nothing stale to pick.
    assert!(refreshing.stats.maintain_calls > 0, "no idle gaps exercised");
    assert_eq!(refreshing.stats.refreshed_rows, 0);
    assert_eq!(plain.stats.maintain_calls, 0);
}

#[test]
fn refresh_in_gaps_reprograms_an_aged_engine_without_touching_batch_ops() {
    // On an aged engine the in-gap maintain increments genuinely
    // re-program rows (one-time ledger), while cumulative marginal batch
    // ops still match the aged oracle bit for bit — marginal work is a
    // function of the workload, not of device state or refresh activity.
    let ds = SearchDataset::generate("fd", 43, 60, 32, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();

    let mut oracle_engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    oracle_engine.advance_age(1.0e9);
    let oracle = oracle_engine.search_batch(&queries, &be).unwrap();

    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    engine.advance_age(1.0e9);
    let rounds_before = engine.program_ops().program_rounds;

    let trace = ArrivalTrace::uniform(queries.len(), 50);
    let served = FrontDoor::new(CoalescePolicy::SizeDeadline {
        max_batch: 8,
        deadline_ticks: 5,
    })
    .with_refresh(RefreshPolicy {
        max_age_seconds: 1.0,
        budget: 2,
    })
    .serve_trace(&mut engine, &queries, &trace, &be)
    .unwrap();

    // Marginal ops are oracle-identical even though rows re-programmed
    // mid-trace (scores legitimately differ once refresh heals drift —
    // that is the point of refreshing).
    assert_eq!(served.ops, oracle.ops, "refresh leaked into marginal ops");
    assert!(served.stats.maintain_calls > 0);
    assert!(served.stats.refreshed_rows > 0, "aged rows never refreshed");
    assert!(
        engine.program_ops().program_rounds > rounds_before,
        "refresh work missing from the one-time ledger"
    );
    for out in &served.outcomes {
        assert_eq!(out.ops.program_rounds, 0, "programming charged to a batch");
    }
    // Later batches saw healed rows: refresh telemetry reached serving.
    assert!(served.outcomes.last().unwrap().health.refreshes > 0);
}

#[test]
fn bounded_queue_backpressure_preserves_results() {
    // A queue bound below the fill target forces partial-tile
    // backpressure flushes on a burst — results still match the oracle.
    let ds = SearchDataset::generate("fd", 47, 60, 40, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();
    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();
    let oracle = engine.search_batch(&queries, &be).unwrap();

    let trace = ArrivalTrace::uniform(queries.len(), 0);
    let served = FrontDoor::new(CoalescePolicy::Size { max_batch: 64 })
        .with_capacity(6)
        .serve_trace(&mut engine, &queries, &trace, &be)
        .unwrap();

    assert_matches_oracle(&served, &oracle.pairs, &oracle.matched, &oracle.ops, "bp");
    assert!(served.stats.backpressure_flushes > 0, "bound never hit");
    assert!(served.stats.max_queue_depth <= 6);
    // 40 requests through a 6-slot queue: 6 backpressure flushes of 6
    // plus the final drain of 4.
    assert_eq!(served.stats.batches, 7);
    assert_eq!(served.stats.drain_flushes, 1);
}

#[test]
fn telemetry_reflects_the_schedule_not_just_the_results() {
    // Deadline policy under a trickle: every flush is deadline-fired,
    // wait percentiles equal the deadline, fill fraction is 1/max_batch.
    let ds = SearchDataset::generate("fd", 53, 60, 16, 0.8, 0.2, 0, 0);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let be = BackendDispatcher::reference();
    let mut engine = SearchEngine::program(cfg(), &ds, &be).unwrap();

    let trace = ArrivalTrace::uniform(queries.len(), 100);
    let served = FrontDoor::new(CoalescePolicy::SizeDeadline {
        max_batch: 8,
        deadline_ticks: 10,
    })
    .serve_trace(&mut engine, &queries, &trace, &be)
    .unwrap();

    // Interarrival (100) >> deadline (10): every request waits exactly
    // the deadline, alone in its batch.
    assert_eq!(served.stats.batches as usize, queries.len());
    assert_eq!(
        served.stats.deadline_flushes + served.stats.drain_flushes,
        served.stats.batches
    );
    assert_eq!(served.stats.size_flushes, 0);
    assert_eq!(served.stats.p50_wait_ticks, 10);
    assert_eq!(served.stats.p99_wait_ticks, 10);
    assert_eq!(served.stats.max_wait_ticks, 10);
    assert!((served.stats.mean_fill_fraction - 1.0 / 8.0).abs() < 1e-12);

    // Size policy on a burst: one full flush per fill target, zero wait.
    let trace = ArrivalTrace::uniform(queries.len(), 0);
    let served = FrontDoor::new(CoalescePolicy::Size { max_batch: 8 })
        .serve_trace(&mut engine, &queries, &trace, &be)
        .unwrap();
    assert_eq!(served.stats.batches, 2);
    assert_eq!(served.stats.size_flushes, 2);
    assert_eq!(served.stats.max_wait_ticks, 0);
    assert!((served.stats.mean_fill_fraction - 1.0).abs() < 1e-12);
}
