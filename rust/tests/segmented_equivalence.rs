//! Zero-copy serving equivalence: segmented jobs over a borrowed
//! bucket-contiguous panel — executed through the cache-blocked kernel by
//! every backend — must be **bit-identical** to gathering the same
//! candidate rows into a dense matrix and scoring through the unblocked
//! reference transfer function, for scores AND physical op counts. Three
//! levels:
//!
//! 1. a randomized property test over ragged segment lists (empty
//!    segments, single-row buckets, ranges straddling the 128-row tile
//!    boundary, overlapping ranges) across backends and thread counts;
//! 2. the engine's `search_batch` against an independent gathered oracle
//!    reconstructed from the public layout API (`bucket_row_range`,
//!    `logical_of_physical`, `noisy_row`);
//! 3. sharded-vs-monolithic serving on the segmented path (the layout is
//!    per-shard; the merge contract must not see it).

use std::ops::Range;

use specpcm::array::{imc_mvm_ref, AdcConfig};
use specpcm::backend::{BackendDispatcher, MvmBackend, MvmJob, ParallelBackend, RefBackend};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{SearchEngine, ShardedSearchEngine};
use specpcm::energy::OpCounts;
use specpcm::ms::bucket::candidate_keys_open;
use specpcm::ms::synth::PTM_SHIFTS;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::util::Rng;

fn rand_packed(rng: &mut Rng, len: usize, n: i64) -> Vec<f32> {
    (0..len).map(|_| rng.range_i64(-n, n) as f32).collect()
}

fn gather_rows(panel: &[f32], segs: &[Range<usize>], cp: usize) -> Vec<f32> {
    let mut g = Vec::new();
    for s in segs {
        g.extend_from_slice(&panel[s.start * cp..s.end * cp]);
    }
    g
}

#[test]
fn ragged_segments_bit_identical_to_gathered_path() {
    let mut rng = Rng::new(0x5e6);
    for trial in 0..25u64 {
        let panel_rows = 1 + rng.below(300);
        let cp = [128usize, 256][rng.below(2)];
        let nq = rng.below(6); // includes nq = 0
        let panel = rand_packed(&mut rng, panel_rows * cp, 3);
        let queries = rand_packed(&mut rng, nq * cp, 3);
        let adc = [AdcConfig::new(6, 512.0), AdcConfig::new(3, 128.0)][rng.below(2)];

        // Random ragged ranges (may overlap — stricter than the engine
        // ever produces), plus deliberate edge shapes: an empty segment,
        // a single-row bucket, and a range straddling the 128-row tile
        // boundary when the panel is big enough.
        let mut segs: Vec<Range<usize>> = Vec::new();
        for _ in 0..rng.below(6) {
            let a = rng.below(panel_rows + 1);
            let b = rng.below(panel_rows + 1);
            segs.push(a.min(b)..a.max(b));
        }
        let single = rng.below(panel_rows);
        segs.push(single..single + 1);
        segs.push(0..0);
        if panel_rows > 130 {
            segs.push(120..135);
        }

        let gathered = gather_rows(&panel, &segs, cp);
        let n_cand: usize = segs.iter().map(|s| s.len()).sum();
        let want = imc_mvm_ref(&queries, &gathered, nq, n_cand, cp, adc);

        let seg_job = MvmJob::segmented(&queries, nq, &panel, &segs, cp, adc);
        assert_eq!(seg_job.nr, n_cand, "trial {trial}");
        let dense_job = MvmJob::new(&queries, nq, &gathered, n_cand, cp, adc);
        // Identical physical work no matter the layout.
        assert_eq!(seg_job.bank_ops(), dense_job.bank_ops(), "trial {trial}");

        // Reference backend, segmented and dense.
        assert_eq!(RefBackend.mvm_scores(&seg_job).unwrap(), want, "trial {trial} ref/seg");
        assert_eq!(RefBackend.mvm_scores(&dense_job).unwrap(), want, "trial {trial} ref/dense");

        // Parallel backend across thread counts, writing into a reused
        // poisoned buffer.
        let mut out = vec![f32::NAN; nq * n_cand];
        for threads in [1usize, 2, 8] {
            out.fill(f32::NAN);
            ParallelBackend::new(threads)
                .mvm_scores_into(&seg_job, &mut out)
                .unwrap();
            assert_eq!(out, want, "trial {trial} parallel x{threads}");
        }

        // Dispatcher: identical scores and identical op charge for the
        // segmented and gathered forms of the same candidate set.
        for disp in [BackendDispatcher::reference(), BackendDispatcher::parallel(2)] {
            let mut ops_seg = OpCounts::default();
            let mut ops_dense = OpCounts::default();
            let got = disp.execute(&seg_job, &mut ops_seg).unwrap();
            assert_eq!(got, want, "trial {trial} dispatcher {}", disp.primary_name());
            disp.execute(&dense_job, &mut ops_dense).unwrap();
            assert_eq!(ops_seg, ops_dense, "trial {trial}");
        }
    }
}

/// The PR 6 column-striped path (`nq < threads`, large candidate span)
/// against the gathered scalar oracle, across thread counts and stripe
/// overrides. The panel carries non-integer (noisy-conductance-like)
/// values so f32 rounding is live: any drift from the lane-ordered
/// accumulation contract — in the striped fan-out or the kernel — breaks
/// bit-identity here, where integer-only data would mask it.
#[test]
fn single_query_large_span_bit_identical_to_gathered_path() {
    let mut rng = Rng::new(0x1a9e);
    let (panel_rows, cp) = (2200usize, 256usize);
    let panel: Vec<f32> = (0..panel_rows * cp)
        .map(|_| rng.range_i64(-3, 3) as f32 + rng.range_i64(-400, 400) as f32 / 7000.0)
        .collect();
    let queries = rand_packed(&mut rng, cp, 3);
    let adc = AdcConfig::new(6, 512.0);
    // Ragged large-span segments: tile-straddling, single-row, empty.
    let segs: Vec<Range<usize>> = vec![0..700, 720..721, 800..800, 900..1930, 2000..2200];
    let gathered = gather_rows(&panel, &segs, cp);
    let n_cand: usize = segs.iter().map(|s| s.len()).sum();
    let want = imc_mvm_ref(&queries, &gathered, 1, n_cand, cp, adc);

    let job = MvmJob::segmented(&queries, 1, &panel, &segs, cp, adc);
    let mut out = vec![f32::NAN; n_cand];
    for threads in [1usize, 2, 4, 8] {
        for stripe_rows in [0usize, 128, 384, 1 << 20] {
            out.fill(f32::NAN);
            ParallelBackend::new(threads)
                .with_stripe_rows(stripe_rows)
                .mvm_scores_into(&job, &mut out)
                .unwrap();
            assert_eq!(out, want, "threads={threads} stripe_rows={stripe_rows}");
        }
    }

    // Op charge through the dispatcher is stripe-shape-independent too.
    let disp = BackendDispatcher::parallel(8);
    let mut ops = OpCounts::default();
    out.fill(f32::NAN);
    disp.execute_into(&job, &mut out, &mut ops).unwrap();
    assert_eq!(out, want);
    assert_eq!(ops.mvm_ops, job.bank_ops());
}

fn search_cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        hd_dim: 2048,
        bucket_width: 5.0,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    }
}

/// Reconstruct the pre-layout gathered scoring path from the engine's
/// public API and assert `search_batch` (the segmented path) reproduces
/// it bit-for-bit: per-query candidate rows in ascending *logical* order,
/// gathered into a dense matrix, scored through the unblocked reference
/// transfer function, merged with the first-strictly-greater scan.
#[test]
fn engine_search_batch_matches_gathered_oracle() {
    let ds = SearchDataset::generate("t", 51, 60, 30, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let cfg = search_cfg();
    let engine = SearchEngine::program(cfg.clone(), &ds, &be).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let batch = engine.search_batch(&queries, &be).unwrap();

    let cp = engine.packed_width();
    let adc = AdcConfig::default_for_packing(cfg.adc_bits, cfg.packing());
    let (packed, _) = engine.encode_queries(&queries, &be).unwrap();

    let mut oracle_pairs = Vec::with_capacity(queries.len());
    let mut oracle_matched = Vec::with_capacity(queries.len());
    for (qi, q) in queries.iter().enumerate() {
        let keys = candidate_keys_open(q.charge, q.precursor_mz, cfg.bucket_width, &PTM_SHIFTS);
        let mut cand: Vec<usize> = keys
            .iter()
            .filter_map(|k| engine.bucket_row_range(k))
            .flat_map(|r| r.map(|p| engine.logical_of_physical()[p]))
            .collect();
        cand.sort_unstable();
        cand.dedup();

        let mut best_t = f32::NEG_INFINITY;
        let mut best_d = f32::NEG_INFINITY;
        let mut matched: Option<u32> = None;
        if !cand.is_empty() {
            let mut rows = Vec::with_capacity(cand.len() * cp);
            for &ri in &cand {
                rows.extend_from_slice(engine.noisy_row(ri));
            }
            let q_row = &packed[qi * cp..(qi + 1) * cp];
            let scores = imc_mvm_ref(q_row, &rows, 1, cand.len(), cp, adc);
            for (ci, &ri) in cand.iter().enumerate() {
                let s = scores[ci];
                if ri < engine.n_targets() {
                    if s > best_t {
                        best_t = s;
                        matched = ds.library[ri].peptide_id;
                    }
                } else if s > best_d {
                    best_d = s;
                }
            }
        }
        oracle_pairs.push((best_t, best_d));
        oracle_matched.push(matched);
    }

    assert_eq!(batch.pairs, oracle_pairs, "segmented scores diverge from gathered oracle");
    assert_eq!(batch.matched, oracle_matched, "matched peptides diverge");
}

#[test]
fn sharded_segmented_serving_matches_monolithic() {
    // 3 shards of 12 banks vs one 36-bank monolith; each shard lays its
    // own rows out bucket-contiguously, yet results and total op counts
    // must match the monolithic engine exactly.
    let ds = SearchDataset::generate("t", 53, 90, 40, 0.8, 0.2, 0, 0);
    let be = BackendDispatcher::reference();
    let mono_cfg = SpecPcmConfig {
        num_banks: 36,
        ..search_cfg()
    };
    let shard_cfg = SpecPcmConfig {
        num_banks: 12,
        ..search_cfg()
    };
    let mono = SearchEngine::program(mono_cfg, &ds, &be).unwrap();
    let sharded = ShardedSearchEngine::program(shard_cfg, &ds, &be, 3).unwrap();
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();

    let mono_batch = mono.search_batch(&queries, &be).unwrap();
    let shard_batch = sharded.search_batch(&queries, &be).unwrap();
    assert_eq!(shard_batch.pairs, mono_batch.pairs);
    assert_eq!(shard_batch.matched, mono_batch.matched);
    assert_eq!(shard_batch.ops, mono_batch.ops);
}
