//! Integration: end-to-end pipelines through the PJRT artifact backend
//! agree with the rust reference backend and hit quality floors on
//! synthetic workloads (DESIGN.md §8).
//!
//! Feature-gated: the whole file needs `--features pjrt` (plus a vendored
//! `xla` crate and a built `artifacts/` tree). The artifact-free
//! backend-equivalence coverage lives in `backend_equivalence.rs` and runs
//! on the default feature set.
#![cfg(feature = "pjrt")]

use std::cell::RefCell;
use std::rc::Rc;

use specpcm::backend::{BackendDispatcher, PjrtBackend};
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchPipeline};
use specpcm::ms::{ClusteringDataset, SearchDataset};
use specpcm::runtime::Runtime;

/// PJRT dispatcher + a telemetry handle on its runtime, or skip when the
/// artifacts tree has not been built.
fn pjrt_or_skip() -> Option<(BackendDispatcher, Rc<RefCell<Runtime>>)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let mut rt = Runtime::load(dir).expect("runtime loads");
    rt.manifest.dir = std::path::PathBuf::from(dir);
    let backend = PjrtBackend::new(rt);
    let handle = backend.shared_runtime();
    Some((BackendDispatcher::with_pjrt(backend, 0.3), handle))
}

fn clustering_cfg() -> SpecPcmConfig {
    SpecPcmConfig {
        num_banks: 64,
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    }
}

#[test]
fn clustering_artifact_path_matches_reference_path() {
    let Some((backend, rt)) = pjrt_or_skip() else { return };
    let cfg = clustering_cfg();
    let ds = ClusteringDataset::generate("t", 21, 10, 4, 6, 8, 0);

    let via_artifacts = ClusteringPipeline::new(cfg.clone())
        .run(&ds, &backend)
        .unwrap();
    let via_rust = ClusteringPipeline::new(cfg)
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();

    assert!(rt.borrow().total_execs() > 0, "artifact path actually executed");
    // Same seeds, bit-exact MVM -> identical quality curves & op counts.
    assert_eq!(via_artifacts.ops.mvm_ops, via_rust.ops.mvm_ops);
    for (a, b) in via_artifacts.curve.iter().zip(&via_rust.curve) {
        assert_eq!(a.clustered_ratio, b.clustered_ratio, "t={}", a.threshold);
        assert_eq!(a.incorrect_ratio, b.incorrect_ratio, "t={}", a.threshold);
    }
}

#[test]
fn search_artifact_path_matches_reference_path() {
    let Some((backend, rt)) = pjrt_or_skip() else { return };
    let cfg = SpecPcmConfig {
        hd_dim: 2048,
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate("t", 22, 50, 60, 0.8, 0.2, 0, 0);

    let via_artifacts = SearchPipeline::new(cfg.clone()).run(&ds, &backend).unwrap();
    let via_rust = SearchPipeline::new(cfg)
        .run(&ds, &BackendDispatcher::reference())
        .unwrap();

    assert!(rt.borrow().total_execs() > 0);
    assert_eq!(via_artifacts.identified, via_rust.identified);
    assert_eq!(via_artifacts.correct, via_rust.correct);
    assert_eq!(
        via_artifacts.identified_peptides,
        via_rust.identified_peptides
    );
}

#[test]
fn clustering_quality_floor_through_artifacts() {
    let Some((backend, _rt)) = pjrt_or_skip() else { return };
    let ds = ClusteringDataset::generate("t", 23, 15, 4, 8, 10, 0);
    let out = ClusteringPipeline::new(clustering_cfg())
        .run(&ds, &backend)
        .unwrap();
    let q = clustered_at_incorrect(&out.curve, 0.02);
    assert!(q > 0.3, "clustered ratio {q} at 2% incorrect");
}

#[test]
fn search_default_d8192_uses_encoder_artifact_and_size_router() {
    // The paper-default search dimension (D=8192, n=3) must run its
    // encoding through the compiled enc_pack_d8192_n3 artifact. The
    // dispatcher sends the *small* candidate buckets of this synthetic set
    // to the bit-identical rust path (utilization < 30% of the fixed B x R
    // artifact geometry) — that routing is part of the contract.
    let Some((backend, rt)) = pjrt_or_skip() else { return };
    let cfg = SpecPcmConfig {
        num_banks: 64,
        ..SpecPcmConfig::paper_search()
    };
    assert_eq!(cfg.hd_dim, 8192);
    let ds = SearchDataset::generate("t", 24, 30, 40, 0.8, 0.2, 0, 0);
    let out = SearchPipeline::new(cfg).run(&ds, &backend).unwrap();
    assert!(
        rt.borrow().exec_counts.contains_key("enc_pack_d8192_n3"),
        "encoder artifact executed, got {:?}",
        rt.borrow().exec_counts.keys().collect::<Vec<_>>()
    );
    assert!(out.identified > 10, "identified {}", out.identified);
    assert!(out.correct as f64 >= 0.8 * out.identified as f64);
}

#[test]
fn dense_workload_routes_mvm_to_artifact() {
    // A candidate-dense workload must cross the dispatcher's utilization
    // threshold and execute the compiled MVM variant.
    let Some((backend, rt)) = pjrt_or_skip() else { return };
    let cfg = SpecPcmConfig {
        hd_dim: 2048, // c = 768 variant
        num_banks: 64,
        bucket_width: 2000.0, // one giant bucket: all refs are candidates
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::generate("t", 25, 400, 80, 0.8, 0.2, 0, 0);
    let out = SearchPipeline::new(cfg).run(&ds, &backend).unwrap();
    assert!(
        rt.borrow().exec_counts.contains_key("mvm_c768"),
        "expected mvm_c768 executions, got {:?}",
        rt.borrow().exec_counts.keys().collect::<Vec<_>>()
    );
    assert!(out.identified > 10, "identified {}", out.identified);
}
