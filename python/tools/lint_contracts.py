#!/usr/bin/env python3
"""Static contract linter for `rust/src/**` — the six standing invariants.

Usage:
    python3 python/tools/lint_contracts.py [--root DIR]
    python3 python/tools/lint_contracts.py --explain RULE   # or `--explain all`
    python3 python/tools/lint_contracts.py --list

Six PRs of rust_pallas growth revolve around one contract: backend /
layout / shard choices change host wall time only, never scores or
`OpCounts`. The dynamic equivalence suites in `rust/tests/` enforce that
for the shapes they happen to exercise; this linter rejects, at analysis
time, the *code shapes* that have historically broken it. It is a
line/token-level scanner (comments and string literals are stripped,
brace depth / `#[cfg(test)]` blocks / enclosing `fn` and `impl` are
tracked) with one small rule per contract:

  C1-REASSOC   f32 loop accumulation outside the lane primitives
  C2-CHARGE    decentralized mutation of `OpCounts` fields
  C3-SYNC      RefCell/Rc in Sync engine code; bare `Mutex::lock()`
  C4-RNG       noise-RNG construction outside `ProgramContext`
  C5-UNSAFE    `unsafe` without a `// SAFETY:` comment
  C6-TIME      `std::time` (Instant/SystemTime) in non-test src code

Every rule supports a per-line allowlist marker, placed on the offending
line or the line directly above it:

    // lint: <tag>-ok (<reason>)

where `<tag>` is the rule's marker tag (see `--explain`) and `<reason>`
is mandatory prose — an empty reason is itself a finding. Findings are
reported as `file:line: RULE-ID message`.

Exit codes: 0 clean, 1 findings, 2 usage error. stdlib-only; no third
party imports — this runs in CI before any toolchain is installed.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# --------------------------------------------------------------------------
# Rule registry
# --------------------------------------------------------------------------

OPCOUNT_FIELDS = (
    "mvm_ops",
    "program_rounds",
    "verify_rounds",
    "row_reads",
    "encode_spectra",
    "features",
    "pack_elements",
    "merge_elements",
)

#: Functions that ARE the lane-accumulation contract (PR 6): raw f32
#: accumulation inside their bodies is the canonical implementation, not a
#: violation.
LANE_PRIMITIVES = ("lane_tile_dot", "lane_tree_reduce", "imc_mvm_ref")

#: (impl, fn) pairs blessed to mutate `OpCounts` fields (PR 4's central
#: charging sites).
CHARGE_SITES = (
    ("GroupCharges", "charge"),
    ("MvmJob", "count_ops"),
    ("HdFrontend", "count_encode_ops"),
)


class Rule:
    def __init__(self, rule_id, tag, title, explain):
        self.rule_id = rule_id
        self.tag = tag  # allowlist marker suffix: `// lint: <tag>-ok (...)`
        self.title = title
        self.explain = explain


RULES = {
    "C1-REASSOC": Rule(
        "C1-REASSOC",
        "reassoc",
        "float-accumulation discipline (lane contract)",
        """\
Invariant: every f32 sum on the scoring path uses the PR 6 lane
contract — 8 `k % 8` lanes combined by the fixed tree reduce
`((l0+l4)+(l2+l6))+((l1+l5)+(l3+l7))` — so that SIMD-friendly kernels,
the scalar oracle, and every backend produce bit-identical scores.
f32 addition is not associative; an ad-hoc `+=` loop or `.sum::<f32>()`
silently picks a different association and breaks bit-identity in the
last ulp, which the equivalence suites then catch only for the shapes
they exercise.

Flagged shapes, inside `array/`, `backend/`, `hd/` (non-test code):
  * `+=` into an f32 accumulator declared in the same function
    (`let mut acc = 0f32` / `[0f32; N]` / `vec![0f32; ..]`, including
    `&mut` slice aliases and `iter_mut()` loop bindings over it)
  * `.sum::<f32>()`
  * `.fold(` seeded with a float literal (`0.0`, `0f32`, `0.0f32`)
  * a dot-product-shaped untyped sum: `.map(|..| a * b).sum()`

Blessed: bodies of the lane primitives themselves — lane_tile_dot,
lane_tree_reduce, imc_mvm_ref (`array/transfer.rs`) — plus `#[cfg(test)]`
code and lines carrying `// lint: reassoc-ok (<reason>)`.

Dynamic backing: `rust/tests/backend_equivalence.rs`,
`rust/tests/segmented_equivalence.rs`, and the pinned-bits regression
test `array::transfer::tests::lane_order_pinned_bits` (hash 0xbff5_c288),
which fails if the association order drifts at all.""",
    ),
    "C2-CHARGE": Rule(
        "C2-CHARGE",
        "charge",
        "central OpCounts charging",
        """\
Invariant: `OpCounts` fields are charged at a small set of central
sites, so op accounting stays bit-identical across backend / shard /
layout choices. PR 4 had to unwind exactly this bug class: per-shard
charging of `MvmJob::bank_ops` over-counted because the
`ceil(rows / 128)` tile term is not linear across row splits — only a
merged, centralized charge is. Scattering `ops.mvm_ops += ..` through
new code reintroduces that class.

Flagged shape: `<recv>.<field> += / -= / =` for any OpCounts field
(%s)
where `<recv>` is `self`, `ops`, or a `*ops`-suffixed binding, in any
non-test file that imports `energy::OpCounts`.

Blessed charging sites: `GroupCharges::charge` (merged candidate
tiling), `MvmJob::count_ops` (the bank_ops consumer), and
`HdFrontend::count_encode_ops`; plus the defining module
`energy/model.rs`, `#[cfg(test)]` code, and lines carrying
`// lint: charge-ok (<reason>)`. Whole-struct merges
(`ops += &other`, `OpCounts::add`) are always fine — they are how
charges propagate, not where they originate.

Dynamic backing: op-count equality asserts in
`rust/tests/engine_equivalence.rs` and the sharded-vs-monolithic suite
in `rust/tests/segmented_equivalence.rs`."""
        % ", ".join(OPCOUNT_FIELDS),
    ),
    "C3-SYNC": Rule(
        "C3-SYNC",
        "sync",
        "Sync-engine discipline",
        """\
Invariant: `SearchEngine` (and everything the shard fan-out touches) is
`Sync` — shared state is `Mutex`/`atomic`, never `RefCell`/`Rc`, so
per-shard engines can be driven from scoped threads. And every
`Mutex::lock()` goes through `util::sync::lock_unpoisoned(&m, what)`,
which panics with a *named* lock on poisoning, instead of a bare
`.lock().unwrap()` whose panic message identifies nothing.

Flagged shapes:
  * `RefCell` / `Rc` (type or path use) in `coordinator/`, `backend/`,
    `encode/` non-test code
  * `.lock()` anywhere in `rust/src` outside `util/sync.rs` itself
    (`try_lock()` is fine: the non-blocking fallback pattern in
    `ScoreScratch` is part of the design)

Blessed: `util/sync.rs` (the helper's own implementation),
`#[cfg(test)]` code, and lines carrying `// lint: sync-ok (<reason>)`.

Dynamic backing: the `engine_is_sync_shareable` unit test in
`coordinator/engine.rs` (compile-time `Sync` assertion) and the scoped
thread fan-out exercised by `rust/tests/segmented_equivalence.rs`.""",
    ),
    "C4-RNG": Rule(
        "C4-RNG",
        "rng",
        "RNG chaining discipline",
        """\
Invariant: programming-noise RNG state is *chained* shard-to-shard
(`ProgramContext::with_rng`, `SearchEngine::program_with_rng` /
`noise_rng_state`), because write-verify early exit makes per-row RNG
consumption data-dependent — re-seeding per shard would desynchronize
sharded engines from the monolithic reference and break score
bit-identity. Fault injection (PR 8) rides the *same* chained stream:
`FaultModel::apply` consumes exactly one draw per cell immediately after
that cell's noise draws (zero when faults are disabled), so injected
stuck-at/program-fail cells are bit-identical across shard counts too.
The one other legal root is `ProgramContext::refresh_rng`, which derives
a fresh stream per (global row, refresh epoch): refresh happens *after*
programming, outside the chained stream, and keying it on the global row
index keeps re-programmed conductances independent of which shard holds
the row or the order buckets refresh in. So `Rng::new` construction in
engine code is only legal inside `ProgramContext` (the root of the
chained noise stream and of the per-(row, epoch) refresh streams);
everything downstream must thread an existing `Rng` through.

Flagged shape: `Rng::new(..)` in `coordinator/`, `backend/`, `encode/`,
`isa/` non-test code.

Blessed: the `impl ProgramContext` block, files under `config/` and
`util/` (the generator itself), `#[cfg(test)]` code, and lines carrying
`// lint: rng-ok (<reason>)`. Dataset/baseline generators (`ms/`,
`baselines/`, `cluster/`) are out of scope — their RNGs seed synthetic
data, not device noise.

Dynamic backing: the chained-RNG bit-identity asserts in
`rust/tests/segmented_equivalence.rs` (sharded == monolithic scores
under programming noise) and the aged/faulted/refreshed schedule
equivalence in `rust/tests/drift_equivalence.rs`.""",
    ),
    "C5-UNSAFE": Rule(
        "C5-UNSAFE",
        "safety",
        "unsafe hygiene",
        """\
Invariant: the crate contains no `unsafe` code at all — enforced by
`#![forbid(unsafe_code)]` in `rust/src/lib.rs` (this rule fails if that
attribute is ever dropped). Should a future PR deliberately relax the
forbid for a vetted kernel, every `unsafe` keyword must carry a
`// SAFETY:` comment on the same line or within the three lines above
it, stating the proof obligation being discharged.

Flagged shapes:
  * `rust/src/lib.rs` missing `#![forbid(unsafe_code)]`
  * `unsafe` (non-comment, non-string, non-test) without a nearby
    `// SAFETY:` comment

Blessed: `#[cfg(test)]` code and lines carrying
`// lint: safety-ok (<reason>)` — though prefer a real SAFETY comment.

Dynamic backing: the allowed-to-fail nightly Miri CI step over the
`array`/`hd` kernel unit tests, which would catch UB dynamically if
unsafe code ever lands.""",
    ),
    "C6-TIME": Rule(
        "C6-TIME",
        "time",
        "logical-clock discipline (no wall time in src)",
        """\
Invariant: serving *behavior* — front-door flush deadlines, drift aging,
refresh scheduling, the remote supervisor's request deadlines, retry
backoff and circuit breakers — runs on the deterministic logical clock
(`SearchEngine::advance_age`, `ArrivalTrace` ticks, the supervisor's
attempt clock), never on wall time. That is what makes every serving
trace and every injected fault schedule (`ChaosPlan`, the wire-level
mirror of `device::FaultModel`) replay tick-for-tick: the fault-tolerance
and scheduler equivalence suites re-run byte-identical scenarios and
assert bit-identical results, which a single `Instant::now()` on a
decision path silently destroys. Wall time is host-side *telemetry*
only: `StageTimer` reports how long the host took, it never feeds back
into what gets computed.

Flagged shape: `std::time` / `Instant` / `SystemTime` anywhere in
`rust/src` non-test code. Benches (`rust/benches/`) are out of scope —
measuring host wall time is their job.

Blessed: `#[cfg(test)]` code and lines carrying
`// lint: time-ok (<reason>)` — today exactly the `StageTimer`
wall-clock capture sites in `telemetry/`, which are telemetry by
definition and never influence scores, op counts, or scheduling.

Dynamic backing: the zero-wall-clock seeded chaos schedules in
`rust/tests/worker_fault_tolerance.rs` (kill/hang/corrupt at logical
ticks, exact final clock values asserted) and the trace replay
determinism asserts in `rust/tests/scheduler_equivalence.rs`.""",
    ),
}

TAG_TO_RULE = {r.tag: r.rule_id for r in RULES.values()}

MARKER_RE = re.compile(r"//\s*lint:\s*([a-z0-9]+)-ok\s*(?:\(([^)]*)\))?")


class Finding:
    __slots__ = ("path", "line", "rule_id", "message")

    def __init__(self, path, line, rule_id, message):
        self.path = path  # repo-relative, posix separators
        self.line = line
        self.rule_id = rule_id
        self.message = message

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"{self.path}:{self.line}: {self.rule_id} {self.message}"


# --------------------------------------------------------------------------
# Scanner: comment/string stripping + scope tracking
# --------------------------------------------------------------------------


def strip_line(line, in_block_comment):
    """Return (code, in_block_comment') with comments and string literal
    *contents* removed. Good enough for this crate: no raw strings or
    `'"'` char literals on the scanned paths."""
    out = []
    i, n = 0, len(line)
    while i < n:
        if in_block_comment:
            j = line.find("*/", i)
            if j < 0:
                return "".join(out), True
            in_block_comment = False
            i = j + 2
            continue
        two = line[i : i + 2]
        if two == "//":
            break
        if two == "/*":
            in_block_comment = True
            i += 2
            continue
        c = line[i]
        if c == '"':
            i += 1
            while i < n:
                if line[i] == "\\":
                    i += 2
                    continue
                if line[i] == '"':
                    i += 1
                    break
                i += 1
            out.append('""')
            continue
        out.append(c)
        i += 1
    return "".join(out), in_block_comment


class LineInfo:
    __slots__ = ("lineno", "raw", "code", "in_test", "fn_name", "impl_name", "markers")

    def __init__(self, lineno, raw, code, in_test, fn_name, impl_name, markers):
        self.lineno = lineno
        self.raw = raw
        self.code = code
        self.in_test = in_test
        self.fn_name = fn_name  # innermost enclosing fn (or None)
        self.impl_name = impl_name  # innermost enclosing impl target (or None)
        self.markers = markers  # {tag: reason-or-None} on this raw line


FN_RE = re.compile(r"\bfn\s+([A-Za-z_]\w*)")
# Anchored at line start (modulo indentation / `unsafe`): `impl` in return
# position (`-> impl Iterator<...>`) or argument position (`x: impl Trait`)
# is a type, not a block opener, and must not push a phantom impl scope —
# that would mis-attribute every later brace in the file and break the
# (impl, fn) blessing of the central charging sites.
IMPL_RE = re.compile(r"^\s*(?:unsafe\s+)?impl\b(?:\s*<[^>]*>)?\s+(?:([\w:]+)\s+for\s+)?([\w:]+)")
TEST_ATTR_RE = re.compile(r"#\s*\[\s*(?:cfg\s*\(\s*test\s*\)|test\b)")


def scan_file(text):
    """Parse a Rust source into LineInfo records with scope context."""
    records = []
    in_block = False
    depth = 0
    # Scope stack entries: (open_depth, kind, name). kind in {fn, impl, test}.
    scopes = []
    pending_fn = None
    pending_impl = None
    pending_test = False

    for lineno, raw in enumerate(text.splitlines(), start=1):
        code, in_block = strip_line(raw, in_block)

        if TEST_ATTR_RE.search(code):
            pending_test = True
        m = FN_RE.search(code)
        if m:
            pending_fn = m.group(1)
        m = IMPL_RE.match(code)
        if m:
            target = m.group(2)
            pending_impl = target.rsplit("::", 1)[-1].split("<", 1)[0]

        markers = {}
        for mm in MARKER_RE.finditer(raw):
            reason = (mm.group(2) or "").strip()
            markers[mm.group(1)] = reason or None

        in_test = any(k == "test" for (_, k, _) in scopes)
        fn_name = next((n for (_, k, n) in reversed(scopes) if k == "fn"), None)
        impl_name = next((n for (_, k, n) in reversed(scopes) if k == "impl"), None)
        records.append(LineInfo(lineno, raw, code, in_test, fn_name, impl_name, markers))

        # Update depth and scope stack from this line's braces.
        for ch in code:
            if ch == "{":
                if pending_test:
                    scopes.append((depth, "test", None))
                    pending_test = False
                    pending_fn = None
                    pending_impl = None
                elif pending_fn is not None:
                    scopes.append((depth, "fn", pending_fn))
                    pending_fn = None
                elif pending_impl is not None:
                    scopes.append((depth, "impl", pending_impl))
                    pending_impl = None
                depth += 1
            elif ch == "}":
                depth -= 1
                while scopes and scopes[-1][0] >= depth:
                    scopes.pop()
        if ";" in code:
            # `fn f(..);` in a trait decl / `#[cfg(test)] use ..;` consume
            # the pending state without opening a block.
            pending_fn = None
            pending_test = False
    return records


def allowed(rec, prev, tag):
    """True when `rec` carries (or the previous line carries) a non-empty
    `<tag>-ok` marker."""
    for r in (rec, prev):
        if r is not None and tag in r.markers and r.markers[tag] is not None:
            return True
    return False


# --------------------------------------------------------------------------
# Rules
# --------------------------------------------------------------------------

F32_DECL_RE = re.compile(
    r"let\s+mut\s+([A-Za-z_]\w*)\s*(?::\s*f32\s*)?=\s*"
    r"(?:vec!\s*\[\s*0(?:\.0)?(?:_?f32)?\s*;"  # vec![0f32; ..]
    r"|\[\s*0(?:\.0)?(?:_?f32)?\s*;"  # [0f32; N]
    r"|0(?:\.0)?_?f32\b"  # 0f32 / 0.0f32
    r"|0\.0\s*;?\s*$)"  # `: f32 = 0.0;`
)
ALIAS_RE = re.compile(r"let\s+(?:mut\s+)?([A-Za-z_]\w*)\s*=\s*&mut\s+([A-Za-z_]\w*)\s*\[")
ITER_MUT_RE = re.compile(r"for\s+\(?([^)]*?)\)?\s+in\s+([A-Za-z_]\w*)\s*\.\s*iter_mut\(\)")
ACCUM_RE = re.compile(r"(?:\*\s*)?([A-Za-z_]\w*)\s*(?:\[[^\]]*\]\s*)?\+=")
SUM_F32_RE = re.compile(r"\.\s*sum\s*::\s*<\s*f32\s*>\s*\(\)")
FOLD_F32_RE = re.compile(r"\.\s*fold\s*\(\s*0(?:\.0)?(?:_?f32)?\s*,")
DOT_SUM_RE = re.compile(r"\.map\(\s*\|[^|]*\|[^)]*\*[^)]*\)\s*\.\s*sum\(\)")


def rule_c1(relpath, records, findings):
    if not relpath.startswith(("array/", "backend/", "hd/")):
        return
    tracked_fn = None  # fn whose accumulator set is live
    tracked = set()
    prev = None
    for rec in records:
        if rec.fn_name != tracked_fn:
            tracked_fn = rec.fn_name
            tracked = set()
        skip = rec.in_test or rec.fn_name in LANE_PRIMITIVES or allowed(rec, prev, "reassoc")
        code = rec.code

        m = F32_DECL_RE.search(code)
        if m:
            tracked.add(m.group(1))
        m = ALIAS_RE.search(code)
        if m and m.group(2) in tracked:
            tracked.add(m.group(1))
        m = ITER_MUT_RE.search(code)
        if m and m.group(2) in tracked:
            tracked.update(re.findall(r"[A-Za-z_]\w*", m.group(1)))

        if not skip:
            m = ACCUM_RE.search(code)
            if m and m.group(1) in tracked:
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C1-REASSOC",
                        f"raw f32 accumulation into `{m.group(1)}` outside the lane "
                        "primitives — route through lane_tile_dot/lane_tree_reduce/"
                        "imc_mvm_ref or annotate `// lint: reassoc-ok (<reason>)`",
                    )
                )
            elif SUM_F32_RE.search(code):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C1-REASSOC",
                        "`.sum::<f32>()` picks an unspecified association order — use "
                        "the lane primitives or annotate `// lint: reassoc-ok (<reason>)`",
                    )
                )
            elif FOLD_F32_RE.search(code):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C1-REASSOC",
                        "float-seeded `fold` accumulation — use the lane primitives "
                        "or annotate `// lint: reassoc-ok (<reason>)`",
                    )
                )
            elif DOT_SUM_RE.search(code):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C1-REASSOC",
                        "dot-product-shaped `.map(|..| a * b).sum()` — use the lane "
                        "primitives or annotate `// lint: reassoc-ok (<reason>)`",
                    )
                )
        prev = rec


CHARGE_RE = re.compile(
    r"\b(?:[A-Za-z_]\w*\.)*([A-Za-z_]\w*)\s*\.\s*(%s)\s*(\+=|-=|=(?!=))"
    % "|".join(OPCOUNT_FIELDS)
)


def rule_c2(relpath, records, findings):
    if relpath == "energy/model.rs":
        return  # the defining module
    if not any("OpCounts" in r.code and "use" in r.code for r in records) and not any(
        "energy::OpCounts" in r.code for r in records
    ):
        return
    prev = None
    for rec in records:
        skip = (
            rec.in_test
            or (rec.impl_name, rec.fn_name) in CHARGE_SITES
            or allowed(rec, prev, "charge")
        )
        if not skip:
            m = CHARGE_RE.search(rec.code)
            if m:
                recv, field = m.group(1), m.group(2)
                if recv == "self" or recv == "ops" or recv.endswith("ops"):
                    findings.append(
                        Finding(
                            relpath,
                            rec.lineno,
                            "C2-CHARGE",
                            f"`{recv}.{field}` mutated outside the central charging "
                            "sites (GroupCharges::charge, MvmJob::count_ops, "
                            "HdFrontend::count_encode_ops) — centralize the charge "
                            "or annotate `// lint: charge-ok (<reason>)`",
                        )
                    )
        prev = rec


REFCELL_RE = re.compile(r"\bRefCell\b|\bRc\s*<|\bRc\s*::|use\s+std\s*::\s*(?:cell|rc)\b")
LOCK_RE = re.compile(r"\.\s*lock\s*\(\)")


def rule_c3(relpath, records, findings):
    if relpath == "util/sync.rs":
        return  # the blessed helper's own implementation
    in_engine_dirs = relpath.startswith(("coordinator/", "backend/", "encode/"))
    prev = None
    for rec in records:
        skip = rec.in_test or allowed(rec, prev, "sync")
        if not skip:
            if in_engine_dirs and REFCELL_RE.search(rec.code):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C3-SYNC",
                        "RefCell/Rc in engine code — these types are !Sync/!Send and "
                        "break the scoped-thread shard fan-out; use Mutex/Arc or "
                        "annotate `// lint: sync-ok (<reason>)`",
                    )
                )
            elif LOCK_RE.search(rec.code):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C3-SYNC",
                        "bare `Mutex::lock()` — use "
                        "`util::sync::lock_unpoisoned(&m, \"<what>\")` so poisoning "
                        "panics name the lock, or annotate "
                        "`// lint: sync-ok (<reason>)`",
                    )
                )
        prev = rec


RNG_NEW_RE = re.compile(r"\bRng\s*::\s*new\s*\(")


def rule_c4(relpath, records, findings):
    if not relpath.startswith(("coordinator/", "backend/", "encode/", "isa/")):
        return
    prev = None
    for rec in records:
        skip = (
            rec.in_test
            or rec.impl_name == "ProgramContext"
            or allowed(rec, prev, "rng")
        )
        if not skip and RNG_NEW_RE.search(rec.code):
            findings.append(
                Finding(
                    relpath,
                    rec.lineno,
                    "C4-RNG",
                    "`Rng::new` outside ProgramContext — noise RNG state must be "
                    "chained (ProgramContext::with_rng / noise_rng_state), never "
                    "re-seeded, or the sharded bit-identity contract breaks; "
                    "annotate `// lint: rng-ok (<reason>)` if this stream is "
                    "genuinely independent",
                )
            )
        prev = rec


UNSAFE_RE = re.compile(r"\bunsafe\b")
SAFETY_RE = re.compile(r"//\s*SAFETY:")
FORBID_UNSAFE_RE = re.compile(r"#!\s*\[\s*forbid\s*\(\s*unsafe_code\s*\)\s*\]")


def rule_c5(relpath, records, findings):
    if relpath == "lib.rs" and not any(FORBID_UNSAFE_RE.search(r.code) for r in records):
        findings.append(
            Finding(
                relpath,
                1,
                "C5-UNSAFE",
                "crate root is missing `#![forbid(unsafe_code)]` — the crate is "
                "unsafe-free by contract; restore the forbid (or downgrade to "
                "deny alongside audited unsafe with SAFETY comments)",
            )
        )
    prev = None
    for i, rec in enumerate(records):
        skip = rec.in_test or allowed(rec, prev, "safety")
        if not skip and UNSAFE_RE.search(rec.code) and "forbid" not in rec.code:
            window = records[max(0, i - 3) : i + 1]
            if not any(SAFETY_RE.search(r.raw) for r in window):
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C5-UNSAFE",
                        "`unsafe` without a `// SAFETY:` comment (same line or the "
                        "three lines above) stating the discharged proof obligation",
                    )
                )
        prev = rec


def rule_markers(relpath, records, findings):
    """Marker hygiene: unknown tags and empty reasons are findings."""
    for rec in records:
        for tag, reason in rec.markers.items():
            if tag not in TAG_TO_RULE:
                known = ", ".join(sorted(TAG_TO_RULE))
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        "C0-MARKER",
                        f"unknown allowlist tag `{tag}-ok` (known tags: {known})",
                    )
                )
            elif reason is None:
                findings.append(
                    Finding(
                        relpath,
                        rec.lineno,
                        TAG_TO_RULE[tag],
                        f"allowlist marker `{tag}-ok` needs a non-empty reason: "
                        f"`// lint: {tag}-ok (<why this line is exempt>)`",
                    )
                )


TIME_RE = re.compile(r"\bstd\s*::\s*time\b|\bInstant\b|\bSystemTime\b")


def rule_c6(relpath, records, findings):
    prev = None
    for rec in records:
        skip = rec.in_test or allowed(rec, prev, "time")
        if not skip and TIME_RE.search(rec.code):
            findings.append(
                Finding(
                    relpath,
                    rec.lineno,
                    "C6-TIME",
                    "wall-clock time in src — serving behavior (deadlines, "
                    "backoff, refresh, drift) runs on the deterministic logical "
                    "clock so traces and fault schedules replay tick-for-tick; "
                    "move the measurement to a bench or annotate "
                    "`// lint: time-ok (<reason>)` if it is pure host telemetry",
                )
            )
        prev = rec


RULE_FNS = (rule_c1, rule_c2, rule_c3, rule_c4, rule_c5, rule_c6, rule_markers)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lint_tree(root):
    """Lint every .rs file under `root`/rust/src. Returns a list of
    Findings sorted by (path, line)."""
    src = Path(root) / "rust" / "src"
    findings = []
    for path in sorted(src.rglob("*.rs")):
        relpath = path.relative_to(src).as_posix()
        records = scan_file(path.read_text(encoding="utf-8"))
        for fn in RULE_FNS:
            fn(relpath, records, findings)
    findings.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return findings


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        default=None,
        help="repository root (default: two levels above this script)",
    )
    ap.add_argument(
        "--explain",
        metavar="RULE",
        help="print the contract behind RULE (e.g. C1-REASSOC, or `all`) and exit",
    )
    ap.add_argument("--list", action="store_true", help="list rule IDs and exit")
    args = ap.parse_args(argv)

    if args.list:
        for rule in RULES.values():
            print(f"{rule.rule_id:<12} [{rule.tag}-ok]  {rule.title}")
        return 0

    if args.explain:
        want = args.explain.upper()
        ids = list(RULES) if want == "ALL" else [want]
        unknown = [i for i in ids if i not in RULES]
        if unknown:
            known = ", ".join(RULES)
            print(f"error: unknown rule {unknown[0]} (known: {known})", file=sys.stderr)
            return 2
        for i, rid in enumerate(ids):
            rule = RULES[rid]
            if i:
                print()
            print(f"{rule.rule_id} — {rule.title}")
            print(f"allowlist marker: // lint: {rule.tag}-ok (<reason>)")
            print()
            print(rule.explain)
        return 0

    root = Path(args.root) if args.root else Path(__file__).resolve().parents[2]
    src = root / "rust" / "src"
    if not src.is_dir():
        print(f"error: {src} not found (use --root)", file=sys.stderr)
        return 2

    findings = lint_tree(root)
    for f in findings:
        print(f"rust/src/{f.path}:{f.line}: {f.rule_id} {f.message}")
    if findings:
        per_rule = {}
        for f in findings:
            per_rule[f.rule_id] = per_rule.get(f.rule_id, 0) + 1
        breakdown = ", ".join(f"{k}: {v}" for k, v in sorted(per_rule.items()))
        print(f"\n{len(findings)} finding(s) ({breakdown})", file=sys.stderr)
        print(
            "run with --explain RULE for the contract behind a rule",
            file=sys.stderr,
        )
        return 1
    print("contract lint clean: all six contracts hold")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:  # e.g. `--explain all | head`
        sys.exit(141)  # 128 + SIGPIPE, the shell convention
