#!/usr/bin/env python3
"""Diff two bench JSON files and fail on throughput/accuracy regressions.

Usage:
    python3 python/tools/bench_compare.py BASELINE.json CURRENT.json \
        [--max-regression 0.15] [--accuracy-tolerance 0.02] \
        [--latency-tolerance 0.25]

Both inputs are `BENCH_serving.json` / `BENCH_drift.json` /
`BENCH_frontdoor.json` / `BENCH_remote.json`-shaped files: a flat JSON
array of records, each carrying a `section` ("batch_scoring",
"single_query", "engine_search_batch", "drift_serving",
"serving_frontdoor", "serving_remote", ...), a
`threads` count, and one or more queries-per-second fields
(`qps_gathered`, `qps_segmented`, `qps_served`), accuracy fields
(`accuracy`), and/or queue-latency fields (`p50_wait_ticks`,
`p99_wait_ticks`). Records are matched across files by
`(section, threads, age_seconds, refresh, policy, workers, chaos)` —
fields absent from a record are None in its key, so old-shape files keep
their `(section, threads)` identity, front-door records add their
coalescing `policy`, and remote-worker records add their `workers` count
and `chaos` mode (`none` / `kill` / `degrade`; `workers` 0 rows are the
in-process baseline). For every qps field present in both, the tool reports the
current/baseline ratio and **exits 1** if any measurement dropped by more
than `--max-regression` (default 15%). Accuracy fields are compared
*absolutely* (they are deterministic fractions, not noisy wall-clock
rates): fail when `current < baseline - --accuracy-tolerance` (default
0.02). Latency fields invert the qps direction — *higher* is worse: fail
when `current > baseline * (1 + --latency-tolerance)` (default 0.25;
queue waits are in deterministic logical ticks, but the tolerance leaves
room for intentional policy retuning to be reviewed, not auto-rejected).

Conventions:
* A baseline qps of 0 (or any non-positive / missing value) is an
  *unmeasured sentinel* — e.g. a schema-only baseline committed from a
  machine without the rust toolchain, or a `--tiny` smoke record. Those
  comparisons are skipped with a warning, never failed, so a sentinel
  baseline degrades to a schema check until a real driver run refreshes
  it (`cargo bench --bench serving_throughput`, then copy the emitted
  BENCH_serving.json over the committed one). For accuracy fields 0.0 is
  a legitimate measurement, so only *negative* baselines (-1.0 by
  convention) are sentinels; the same rule applies to latency fields
  (a 0-tick wait is a real measurement — an all-burst trace under a
  size trigger waits nothing).
* Records with neither a qps nor an accuracy field (e.g. a `meta`
  provenance record) are ignored.
* When the two records disagree on the `tiny` flag the comparison is
  skipped with a warning: a `--tiny` smoke run measures a different
  workload and neither its q/s nor its accuracy is commensurable with
  the full-scale baseline. (CI runs the smoke config unconditionally and
  the full config only on big runners; this rule keeps the same compare
  step correct for both.)
* A record key present in the baseline but absent from the current run
  is a hard failure: silently dropping a measured configuration is how
  regressions hide.

Exit codes: 0 ok / nothing comparable, 1 regression or missing record,
2 usage or parse error. stdlib-only.
"""

from __future__ import annotations

import argparse
import json
import sys

QPS_FIELDS = ("qps_gathered", "qps_segmented", "qps_served")
ACC_FIELDS = ("accuracy",)
LAT_FIELDS = ("p50_wait_ticks", "p99_wait_ticks")


def record_key(rec):
    return (
        rec["section"],
        rec.get("threads"),
        rec.get("age_seconds"),
        rec.get("refresh"),
        rec.get("policy"),
        rec.get("workers"),
        rec.get("chaos"),
    )


def key_tag(key):
    section, threads, age, refresh, policy, workers, chaos = key
    tag = f"{section} x{threads}"
    if age is not None:
        tag += f" age={age:g}s"
    if refresh is not None:
        tag += f" refresh={'on' if refresh else 'off'}"
    if policy is not None:
        tag += f" policy={policy}"
    if workers is not None:
        tag += f" workers={workers}"
    if chaos is not None:
        tag += f" chaos={chaos}"
    return tag


def load_records(path):
    try:
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(data, list):
        print(f"error: {path}: expected a JSON array of records", file=sys.stderr)
        sys.exit(2)
    out = {}
    for rec in data:
        if not isinstance(rec, dict) or "section" not in rec:
            continue
        if not any(f in rec for f in QPS_FIELDS + ACC_FIELDS + LAT_FIELDS):
            continue  # meta/provenance record
        key = record_key(rec)
        if key in out:
            print(f"warning: {path}: duplicate record {key}; keeping the last")
        out[key] = rec
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_serving.json to compare against")
    ap.add_argument("current", help="freshly generated BENCH_serving.json")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.15,
        metavar="FRAC",
        help="fail when current qps < baseline * (1 - FRAC) (default 0.15)",
    )
    ap.add_argument(
        "--accuracy-tolerance",
        type=float,
        default=0.02,
        metavar="ABS",
        help="fail when current accuracy < baseline - ABS (default 0.02)",
    )
    ap.add_argument(
        "--latency-tolerance",
        type=float,
        default=0.25,
        metavar="FRAC",
        help="fail when current wait > baseline * (1 + FRAC) (default 0.25)",
    )
    args = ap.parse_args(argv)
    if not 0.0 <= args.max_regression < 1.0:
        ap.error("--max-regression must be in [0, 1)")
    if not 0.0 <= args.accuracy_tolerance < 1.0:
        ap.error("--accuracy-tolerance must be in [0, 1)")
    if args.latency_tolerance < 0.0:
        ap.error("--latency-tolerance must be >= 0")

    base = load_records(args.baseline)
    curr = load_records(args.current)

    def sort_key(k):
        section, threads, age, refresh, policy, workers, chaos = k
        return (
            section,
            threads if threads is not None else -1,
            age if age is not None else -1.0,
            refresh if refresh is not None else False,
            policy if policy is not None else "",
            workers if workers is not None else -1,
            chaos if chaos is not None else "",
        )

    failures = []
    compared = skipped = 0
    for key in sorted(base, key=sort_key):
        tag = key_tag(key)
        if key not in curr:
            failures.append(f"{tag}: present in baseline but missing from current run")
            continue
        b_tiny, c_tiny = base[key].get("tiny"), curr[key].get("tiny")
        if b_tiny != c_tiny:
            print(f"skip  {tag}: scale mismatch (baseline tiny={b_tiny}, current tiny={c_tiny})")
            skipped += 1
            continue
        for field in QPS_FIELDS:
            if field not in base[key] or field not in curr[key]:
                continue
            b, c = base[key][field], curr[key][field]
            if not isinstance(b, (int, float)) or b <= 0:
                print(f"skip  {tag} {field}: baseline unmeasured (sentinel {b!r})")
                skipped += 1
                continue
            if not isinstance(c, (int, float)) or c <= 0:
                failures.append(f"{tag} {field}: current run unmeasured ({c!r})")
                continue
            compared += 1
            ratio = c / b
            verdict = "FAIL" if ratio < 1.0 - args.max_regression else "ok"
            print(f"{verdict:<5} {tag} {field}: {b:.1f} -> {c:.1f} q/s ({ratio:.2f}x)")
            if verdict == "FAIL":
                failures.append(
                    f"{tag} {field}: {ratio:.2f}x of baseline "
                    f"(threshold {1.0 - args.max_regression:.2f}x)"
                )
        for field in ACC_FIELDS:
            if field not in base[key] or field not in curr[key]:
                continue
            b, c = base[key][field], curr[key][field]
            if not isinstance(b, (int, float)) or b < 0:
                print(f"skip  {tag} {field}: baseline unmeasured (sentinel {b!r})")
                skipped += 1
                continue
            if not isinstance(c, (int, float)) or c < 0:
                failures.append(f"{tag} {field}: current run unmeasured ({c!r})")
                continue
            compared += 1
            floor = b - args.accuracy_tolerance
            verdict = "FAIL" if c < floor else "ok"
            print(f"{verdict:<5} {tag} {field}: {b:.3f} -> {c:.3f} (floor {floor:.3f})")
            if verdict == "FAIL":
                failures.append(
                    f"{tag} {field}: {c:.3f} below baseline {b:.3f} "
                    f"- tolerance {args.accuracy_tolerance:.3f}"
                )
        for field in LAT_FIELDS:
            if field not in base[key] or field not in curr[key]:
                continue
            b, c = base[key][field], curr[key][field]
            if not isinstance(b, (int, float)) or b < 0:
                print(f"skip  {tag} {field}: baseline unmeasured (sentinel {b!r})")
                skipped += 1
                continue
            if not isinstance(c, (int, float)) or c < 0:
                failures.append(f"{tag} {field}: current run unmeasured ({c!r})")
                continue
            compared += 1
            ceiling = b * (1.0 + args.latency_tolerance)
            verdict = "FAIL" if c > ceiling else "ok"
            print(
                f"{verdict:<5} {tag} {field}: {b:.1f} -> {c:.1f} ticks "
                f"(ceiling {ceiling:.1f})"
            )
            if verdict == "FAIL":
                failures.append(
                    f"{tag} {field}: {c:.1f} ticks above baseline {b:.1f} "
                    f"* (1 + {args.latency_tolerance:.2f})"
                )

    print(f"\ncompared {compared} measurement(s), skipped {skipped} sentinel(s)")
    if failures:
        print(f"\n{len(failures)} regression check(s) failed:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
