"""AOT-lower every SpecPCM graph variant to HLO text + manifest.json.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` rust crate) rejects with
``proto.id() <= INT_MAX``. The HLO text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

AOT shapes are static, so we emit one executable per model variant (one
per HD dimension / bits-per-cell combination the evaluation sweeps) plus a
manifest the rust runtime uses to pick and pad. Run via ``make artifacts``;
python never runs on the request path.
"""

import argparse
import hashlib
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.pack import padded_packed_len

# Fixed batch geometry (rust pads to these; see rust/src/coordinator/).
BATCH = 64  # spectra per encoder call / queries per MVM call
ROWS = 1024  # reference rows per MVM call = 8 stacked 128-row arrays
FEATURES = 512  # m/z feature positions per preprocessed spectrum
LEVELS = 64  # intensity quantization levels (m in Eq. 1)

# (D, n) variants: paper defaults are D=2048 for clustering, D=8192 for DB
# search, n in {1 (SLC), 2 (MLC2), 3 (MLC3)}; the extra D points feed the
# Fig. S4/S5 dimension sweeps.
ENC_VARIANTS = [
    (512, 3),
    (1024, 3),
    (2048, 1),
    (2048, 2),
    (2048, 3),
    (4096, 3),
    (8192, 1),
    (8192, 3),
]


def mvm_variants() -> list[int]:
    """Distinct padded packed widths implied by ENC_VARIANTS."""
    return sorted({padded_packed_len(d, n) for d, n in ENC_VARIANTS})


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_enc_pack(d: int, n: int) -> str:
    fn = partial(model.encode_pack, n=n)
    lowered = jax.jit(fn).lower(
        _spec((BATCH, FEATURES), jnp.int32),
        _spec((FEATURES, d)),
        _spec((LEVELS, d)),
    )
    return to_hlo_text(lowered)


def lower_mvm(c: int) -> str:
    lowered = jax.jit(model.mvm_scores).lower(
        _spec((BATCH, c)),
        _spec((ROWS, c)),
        _spec((1, 1)),
        _spec((1, 1)),
    )
    return to_hlo_text(lowered)


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    for d, n in ENC_VARIANTS:
        name = f"enc_pack_d{d}_n{n}"
        text = lower_enc_pack(d, n)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "enc_pack",
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "params": {
                    "d": d,
                    "n": n,
                    "batch": BATCH,
                    "features": FEATURES,
                    "levels": LEVELS,
                    "packed": padded_packed_len(d, n),
                },
                "inputs": [
                    {"name": "levels", "shape": [BATCH, FEATURES], "dtype": "s32"},
                    {"name": "id_hvs", "shape": [FEATURES, d], "dtype": "f32"},
                    {"name": "level_hvs", "shape": [LEVELS, d], "dtype": "f32"},
                ],
                "outputs": [
                    {
                        "name": "packed_hvs",
                        "shape": [BATCH, padded_packed_len(d, n)],
                        "dtype": "f32",
                    }
                ],
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    for c in mvm_variants():
        name = f"mvm_c{c}"
        text = lower_mvm(c)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "mvm",
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "params": {"c": c, "batch": BATCH, "rows": ROWS},
                "inputs": [
                    {"name": "queries", "shape": [BATCH, c], "dtype": "f32"},
                    {"name": "refs", "shape": [ROWS, c], "dtype": "f32"},
                    {"name": "adc_lsb", "shape": [1, 1], "dtype": "f32"},
                    {"name": "adc_qmax", "shape": [1, 1], "dtype": "f32"},
                ],
                "outputs": [
                    {"name": "scores", "shape": [BATCH, ROWS], "dtype": "f32"}
                ],
            }
        )
        print(f"lowered {name}: {len(text)} chars")

    manifest = {
        "schema": 1,
        "batch": BATCH,
        "rows": ROWS,
        "features": FEATURES,
        "levels": LEVELS,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build_all(args.out_dir)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
