"""Pallas kernel: HD dimension packing (SpecPCM §III-B).

Dimension packing converts a binary (+/-1) hypervector of length D into a
compressed vector of length ceil(D/n) by summing n adjacent elements, so a
single n-bit MLC PCM cell stores what previously needed n SLC cells. The
packed values lie in {-n, -n+2, ..., n} and are exactly representable by
the 2T2R differential pair.

The kernel runs at encode time inside the near-memory ASIC in the paper;
here it is fused into the encoder artifact so the rust coordinator receives
array-ready packed HVs in one PJRT call.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .imc_mvm import ARRAY_DIM


def packed_len(d: int, n: int) -> int:
    """Packed length before array-tile padding."""
    return -(-d // n)


def padded_packed_len(d: int, n: int) -> int:
    """Packed length padded up to a multiple of ARRAY_DIM (coordinator pads
    queries/refs identically, and zero columns contribute nothing)."""
    p = packed_len(d, n)
    return -(-p // ARRAY_DIM) * ARRAY_DIM


def _pack_kernel(n: int, hv_ref, o_ref):
    x = hv_ref[...]  # (B, ARRAY_DIM * n)
    b = x.shape[0]
    o_ref[...] = x.reshape(b, ARRAY_DIM, n).sum(axis=-1)


def pack_dims(hv, n: int):
    """Pack (B, D) +/-1 hypervectors into (B, padded_packed_len(D, n)).

    D is zero-padded to n * padded_packed_len first; zero elements do not
    change the adjacent-sum, so the tail packed values are exact.
    """
    b, d = hv.shape
    cp = padded_packed_len(d, n)
    dp = cp * n
    if dp != d:
        hv = jnp.pad(hv, ((0, 0), (0, dp - d)))

    if n == 1:
        return hv  # packing is the identity for SLC

    grid = (cp // ARRAY_DIM,)
    return pl.pallas_call(
        lambda hv_ref, o_ref: _pack_kernel(n, hv_ref, o_ref),
        grid=grid,
        in_specs=[pl.BlockSpec((b, ARRAY_DIM * n), lambda j: (0, j))],
        out_specs=pl.BlockSpec((b, ARRAY_DIM), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, cp), jnp.float32),
        interpret=True,
    )(hv)
