"""Pallas kernel: analog in-memory-computing matrix-vector multiply.

This is the paper's compute hot-spot (SpecPCM §III-C): a 128x128 2T2R PCM
array performs a signed dot product between a DAC-driven input vector on the
source lines and the conductances stored in every row simultaneously; the
bit-line partial sums are digitized by a shared 6-bit flash ADC.

TPU adaptation (DESIGN.md §3): one PCM array == one 128x128 Pallas block.
The grid iterates (row-tile, col-tile); each step performs one 128x128
block matmul (MXU-shaped) with the DAC quantization fused on the input side
and the flash-ADC transfer function fused on the partial sums, exactly
mirroring the per-array analog path. Accumulation across col-tiles models
the digital accumulation of per-array partial sums in the near-memory ASIC.

Numeric contract (shared bit-exactly with the rust reference
`rust/src/array/transfer.rs` and the jnp oracle `ref.py`):

    dac(x)   = clip(round_away(x), -2^(DAC_BITS-1), 2^(DAC_BITS-1)-1)
    part     = dac(q_tile) @ g_tile^T                       (f32, exact)
    adc(s)   = clip(round_away(s / lsb), -(qmax+1), qmax) * lsb
    score    = sum over col-tiles of adc(part)

where round_away is round-half-away-from-zero (rust ``f32``'s ``round``).
Conductance non-idealities (programming noise after write-verify, drift)
are applied by the device model *when the refs are programmed*, i.e. the
``g`` argument already carries them; see rust/src/device/.

In-tile accumulation order: the rust *host* kernels canonicalized on a
lane-ordered in-tile sum in PR 6 (eight ``k % 8`` partial-sum lanes
reduced by a fixed binary tree; see ``rust/src/array/transfer.rs``) so
the blocked kernel autovectorizes. This kernel and the jnp oracle keep
whatever association order the MXU/XLA emit. Both stay inside the shared
numeric contract because equality is only asserted on the integer
envelope: packed queries and programmed conductance levels are integral,
per-tile partial sums are integer-valued and exactly representable in
f32, so *every* association order — ascending-k, lane tree, MXU
systolic — produces identical bits. Reassociation only becomes
observable on non-integer data (e.g. noisy analog conductances), which
the rust side covers with its own lane-order regression tests.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Geometry of one PCM bank array (paper Table 1): 128x128 2T2R cells.
ARRAY_DIM = 128
# Source-line DAC resolution (paper Table 1): 3-bit signed.
DAC_BITS = 3

_DAC_LO = float(-(2 ** (DAC_BITS - 1)))  # -4
_DAC_HI = float(2 ** (DAC_BITS - 1) - 1)  # +3


def adc_params(adc_bits: int, clip: float) -> tuple[float, float]:
    """Derive the flash-ADC (lsb, qmax) pair from a bit width and full-scale.

    ``qmax`` is the largest positive code; codes span [-(qmax+1), qmax].
    The rust side computes the same pair in ``rust/src/array/adc.rs``.

    Exactness note: when ``clip`` is a power of two the LSB is too, and the
    whole pipeline (integer packed values -> integer partial sums -> code *
    lsb -> accumulation) stays exactly representable in f32, making the
    XLA-compiled kernel bit-identical to the oracle and to the rust
    reference regardless of FMA contraction. The coordinator therefore
    always rounds the configured full-scale up to a power of two.
    """
    if not 1 <= adc_bits <= 20:
        raise ValueError(f"adc_bits out of range: {adc_bits}")
    qmax = float(2 ** (adc_bits - 1) - 1)
    lsb = clip / float(2 ** (adc_bits - 1))
    return lsb, qmax


def _round_away(x):
    """Round half away from zero — matches rust ``f32::round``."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def _imc_mvm_kernel(lsb_ref, qmax_ref, q_ref, g_ref, o_ref):
    c = pl.program_id(0)
    lsb = lsb_ref[0, 0]
    qmax = qmax_ref[0, 0]

    # DAC: the SL drivers can only realize 2^DAC_BITS signed input levels.
    q = jnp.clip(_round_away(q_ref[...]), _DAC_LO, _DAC_HI)
    g = g_ref[...]

    # Analog MAC across every bank holding this 128-column segment at once:
    # each 128-row slice of `g` is one physical array, but the per-element
    # partial sum is independent of row tiling, so all R rows multiply in a
    # single (B, 128) @ (128, R) MXU-shaped matmul. (Perf note: the original
    # kernel also gridded over 128-row tiles; collapsing the row dimension
    # cut the grid from R/128 * C/128 tiny steps to C/128 large ones — see
    # EXPERIMENTS.md §Perf L1.)
    part = jnp.dot(q, g.T)

    # Flash ADC on the bit-line voltages (per 128-col array segment => per
    # grid step, fused here).
    y = jnp.clip(_round_away(part / lsb), -(qmax + 1.0), qmax) * lsb

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += y


@partial(jax.jit, static_argnames=())
def imc_mvm(queries, refs, adc_lsb, adc_qmax):
    """Batched analog-IMC MVM: scores[b, r] = <queries[b], refs[r]> via PCM.

    Args:
      queries:  (B, C) f32 packed query HVs (values in [-n, n]).
      refs:     (R, C) f32 packed, *device-noised* reference conductances.
      adc_lsb:  (1, 1) f32 — ADC LSB (runtime scalar so one AOT artifact
                serves every ISA ``ADC_bits`` setting).
      adc_qmax: (1, 1) f32 — largest positive ADC code.

    Returns:
      (B, R) f32 scores, the sum of per-array ADC outputs.

    B, R, C must be multiples of ARRAY_DIM (the coordinator pads).
    """
    b, c = queries.shape
    r, c2 = refs.shape
    if c != c2:
        raise ValueError(f"queries C={c} != refs C={c2}")
    if r % ARRAY_DIM or c % ARRAY_DIM:
        raise ValueError(f"R={r}, C={c} must be multiples of {ARRAY_DIM}")

    grid = (c // ARRAY_DIM,)
    return pl.pallas_call(
        _imc_mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda j: (0, 0)),  # adc_lsb
            pl.BlockSpec((1, 1), lambda j: (0, 0)),  # adc_qmax
            pl.BlockSpec((b, ARRAY_DIM), lambda j: (0, j)),  # queries
            pl.BlockSpec((r, ARRAY_DIM), lambda j: (0, j)),  # refs
        ],
        out_specs=pl.BlockSpec((b, r), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(adc_lsb, adc_qmax, queries, refs)
