"""Pure-jnp oracle for every Pallas kernel and L2 graph.

These reference implementations use no Pallas, no tiling and no fused
quantization tricks — just the written-out math from DESIGN.md §2/§3. The
pytest suite asserts the production kernels match them exactly (the whole
pipeline is integer-valued until the ADC divide, so exact equality holds).

Note on accumulation order: ``imc_mvm`` here reduces each 128-column tile
with whatever order ``@`` lowers to; the rust host kernels pin a specific
lane-ordered in-tile sum (``rust/src/array/transfer.rs``, PR 6). On the
integer-valued data this suite tests, partial sums are exact in f32 and
all association orders agree bitwise, so this oracle remains valid for
the Pallas kernel without modeling the lane tree (the float32 model of
the lane order itself lives in
``python/tests/test_blocked_kernel_model.py``).
"""

import jax.numpy as jnp

from .imc_mvm import ARRAY_DIM, DAC_BITS


def round_away(x):
    """Round half away from zero (matches rust ``f32::round``)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def dac(x, bits: int = DAC_BITS):
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    return jnp.clip(round_away(x), float(lo), float(hi))


def adc(s, lsb: float, qmax: float):
    # f32 throughout: the production kernel receives lsb as an f32 runtime
    # scalar, so the oracle must quantize with the identical value.
    lsb = jnp.float32(lsb)
    qmax = jnp.float32(qmax)
    return jnp.clip(round_away(s / lsb), -(qmax + 1.0), qmax) * lsb


def imc_mvm(queries, refs, lsb: float, qmax: float):
    """Tiled-ADC analog MVM, written directly from the math.

    The ADC applies per 128-column tile (per physical array), so the oracle
    must also quantize per tile before accumulating.
    """
    b, c = queries.shape
    r, _ = refs.shape
    assert c % ARRAY_DIM == 0 and r % ARRAY_DIM == 0
    q = dac(queries)
    out = jnp.zeros((b, r), jnp.float32)
    for j in range(c // ARRAY_DIM):
        sl = slice(j * ARRAY_DIM, (j + 1) * ARRAY_DIM)
        part = q[:, sl] @ refs[:, sl].T
        out = out + adc(part, lsb, qmax)
    return out


def pack_dims(hv, n: int):
    """Adjacent-sum packing with zero padding to a 128-multiple output."""
    b, d = hv.shape
    p = -(-d // n)
    cp = -(-p // ARRAY_DIM) * ARRAY_DIM
    hv = jnp.pad(hv, ((0, 0), (0, cp * n - d)))
    return hv.reshape(b, cp, n).sum(axis=-1)


def sign_pm1(x):
    """sign with the tie rule sign(0) = +1 (shared with rust/src/hd)."""
    return jnp.where(x >= 0, 1.0, -1.0)


def encode(levels, id_hvs, level_hvs):
    """ID-level HD encoding (paper Eq. 1): HV = sign(sum over present peaks
    of LV[lvl_f] * ID_f).

    Level 0 means "no peak in this m/z bin" and contributes nothing: MS
    spectra are sparse, and summing empty bins would give all spectra a
    large shared baseline similarity (matches rust/src/hd/encoder.rs).

    levels:    (B, F) int32 quantized intensity level per feature position.
    id_hvs:    (F, D) +/-1 — one random ID hypervector per m/z position.
    level_hvs: (m, D) +/-1 — intensity-level hypervectors.
    """
    gathered = level_hvs[levels]  # (B, F, D)
    mask = (levels > 0).astype(jnp.float32)[:, :, None]
    acc = (gathered * id_hvs[None, :, :] * mask).sum(axis=1)
    return sign_pm1(acc)


def encode_pack(levels, id_hvs, level_hvs, n: int):
    return pack_dims(encode(levels, id_hvs, level_hvs), n)
