"""Layer-1 Pallas kernels for the SpecPCM analog-IMC pipeline.

Every kernel here lowers with ``interpret=True`` so that the resulting HLO
runs on any PJRT backend (the rust coordinator uses the CPU client). Real
TPU lowering would emit Mosaic custom-calls the CPU plugin cannot execute;
see DESIGN.md §2 and /opt/xla-example/README.md.
"""

from .imc_mvm import imc_mvm, adc_params, DAC_BITS, ARRAY_DIM
from .pack import pack_dims
from . import ref

__all__ = [
    "imc_mvm",
    "adc_params",
    "DAC_BITS",
    "ARRAY_DIM",
    "pack_dims",
    "ref",
]
