"""Layer-2 jax graphs for SpecPCM.

Two compute graphs cover the whole paper pipeline; both are AOT-lowered to
HLO text by ``aot.py`` and executed from rust via PJRT:

* ``encode_pack`` — ID-level HD encoding (Eq. 1) followed by dimension
  packing (§III-B). Maps to the paper's near-memory ASIC encoder + packer.
* ``mvm_scores``  — the analog IMC MVM (Pallas kernel ``imc_mvm``), the
  paper's PCM-array hot path used by both clustering distance calculation
  and DB-search Hamming similarity.

The encoder deliberately scans over feature positions: a direct gather of
(B, F, D) level HVs would materialize O(64 * 512 * 8192) floats; the scan
keeps the working set at (B, D) per step and lowers to a compact HLO while
loop that XLA:CPU pipelines well.
"""

import jax
import jax.numpy as jnp

from .kernels import imc_mvm, pack_dims
from .kernels.ref import sign_pm1


def encode(levels, id_hvs, level_hvs):
    """ID-level HD encoding: HV[b] = sign(sum over present peaks of
    LV[levels[b, f]] * ID[f]).

    Level 0 marks an empty m/z bin and contributes nothing (see
    kernels/ref.py::encode and rust/src/hd/encoder.rs for the rationale).

    Args:
      levels:    (B, F) int32 — quantized intensity level per m/z position.
      id_hvs:    (F, D) f32 +/-1 — position (ID) hypervectors.
      level_hvs: (m, D) f32 +/-1 — intensity-level hypervectors.
    Returns:
      (B, D) f32 +/-1 binary hypervectors.
    """
    b, f = levels.shape
    d = id_hvs.shape[1]

    def step(acc, inputs):
        lv_idx, id_hv = inputs  # (B,), (D,)
        mask = (lv_idx > 0).astype(jnp.float32)[:, None]
        acc = acc + jnp.take(level_hvs, lv_idx, axis=0) * id_hv[None, :] * mask
        return acc, None

    acc0 = jnp.zeros((b, d), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (levels.T, id_hvs))
    return sign_pm1(acc)


def encode_pack(levels, id_hvs, level_hvs, n: int):
    """Encoder + dimension packing, fused into one artifact (one PJRT call
    per spectra batch from the rust hot path)."""
    return pack_dims(encode(levels, id_hvs, level_hvs), n)


def mvm_scores(queries, refs, adc_lsb, adc_qmax):
    """Analog IMC similarity scores; see kernels/imc_mvm.py for the contract."""
    return imc_mvm(queries, refs, adc_lsb, adc_qmax)
