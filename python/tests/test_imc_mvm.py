"""Pallas IMC-MVM kernel vs the pure-jnp oracle — the core L1 signal.

Exactness argument: packed HV values are integers in [-n, n]; per-array
partial sums are integers |s| <= 128 * n^2 <= 1152; with a power-of-two ADC
full-scale every ADC output is code * 2^k — all exactly representable in
f32, so kernel and oracle must agree *bit-exactly* (no allclose slack).
Non-power-of-two full-scales are additionally checked to 1-ulp tolerance
(XLA may contract multiply-add into FMA).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import imc_mvm, adc_params, ref, ARRAY_DIM, DAC_BITS


def _scalar(v):
    return jnp.full((1, 1), v, jnp.float32)


def run_kernel(q, g, lsb, qmax):
    return np.asarray(imc_mvm(jnp.array(q), jnp.array(g), _scalar(lsb), _scalar(qmax)))


def run_oracle(q, g, lsb, qmax):
    return np.asarray(ref.imc_mvm(jnp.array(q), jnp.array(g), lsb, qmax))


def rand_packed(rng, shape, n):
    """Random packed-HV-like integer matrix with values in [-n, n]."""
    return rng.integers(-n, n + 1, size=shape).astype(np.float32)


IDEAL_LSB, IDEAL_QMAX = 1.0, float(2**20 - 1)


class TestIdealAdc:
    """With a pass-through ADC the kernel must equal the exact dot product."""

    @pytest.mark.parametrize("n", [1, 2, 3])
    @pytest.mark.parametrize("b,r,c", [(64, 128, 128), (64, 256, 384), (128, 512, 768)])
    def test_equals_integer_dot(self, n, b, r, c):
        rng = np.random.default_rng(42 + n)
        q = rand_packed(rng, (b, c), n)
        g = rand_packed(rng, (r, c), n)
        out = run_kernel(q, g, IDEAL_LSB, IDEAL_QMAX)
        np.testing.assert_array_equal(out, q @ g.T)

    def test_zero_inputs(self):
        q = np.zeros((64, 128), np.float32)
        g = np.zeros((128, 128), np.float32)
        np.testing.assert_array_equal(run_kernel(q, g, IDEAL_LSB, IDEAL_QMAX), 0.0)


class TestQuantizedAdc:
    @pytest.mark.parametrize("adc_bits", [1, 2, 3, 4, 5, 6])
    @pytest.mark.parametrize("clip", [64.0, 256.0, 512.0])
    def test_matches_oracle_pow2_exact(self, adc_bits, clip):
        rng = np.random.default_rng(7)
        q = rand_packed(rng, (64, 384), 3)
        g = rand_packed(rng, (256, 384), 3)
        lsb, qmax = adc_params(adc_bits, clip)
        np.testing.assert_array_equal(
            run_kernel(q, g, lsb, qmax), run_oracle(q, g, lsb, qmax)
        )

    def test_non_pow2_clip_within_ulp(self):
        rng = np.random.default_rng(8)
        q = rand_packed(rng, (64, 384), 3)
        g = rand_packed(rng, (256, 384), 3)
        lsb, qmax = adc_params(6, float(4 * 9 * np.sqrt(128)))
        out, orc = run_kernel(q, g, lsb, qmax), run_oracle(q, g, lsb, qmax)
        np.testing.assert_allclose(out, orc, rtol=1e-6, atol=1e-4)

    def test_saturation_clips_symmetrically(self):
        # All-correlated rows drive partial sums to +/-1152, far past a
        # clip of 64: every tile saturates at (-(qmax+1)) * lsb or qmax * lsb.
        q = np.full((64, 128), 3.0, np.float32)
        g = np.full((128, 128), 3.0, np.float32)
        lsb, qmax = adc_params(6, 64.0)
        out = run_kernel(q, g, lsb, qmax)
        np.testing.assert_array_equal(out, qmax * lsb)
        out_neg = run_kernel(q, -g, lsb, qmax)
        np.testing.assert_array_equal(out_neg, -(qmax + 1.0) * lsb)

    def test_one_bit_adc_is_sign(self):
        rng = np.random.default_rng(9)
        q = rand_packed(rng, (64, 128), 1)
        g = rand_packed(rng, (128, 128), 1)
        lsb, qmax = adc_params(1, 64.0)  # codes in {-1, 0}; lsb = 64
        out = run_kernel(q, g, lsb, qmax)
        assert set(np.unique(out)).issubset({-64.0, 0.0})


class TestDacQuantization:
    def test_dac_clips_out_of_range_inputs(self):
        # Inputs beyond the 3-bit DAC range must clamp to [-4, 3].
        q = np.zeros((64, 128), np.float32)
        q[0, 0] = 100.0
        q[1, 0] = -100.0
        g = np.zeros((128, 128), np.float32)
        g[:, 0] = 1.0
        out = run_kernel(q, g, IDEAL_LSB, IDEAL_QMAX)
        hi = float(2 ** (DAC_BITS - 1) - 1)
        lo = float(-(2 ** (DAC_BITS - 1)))
        np.testing.assert_array_equal(out[0], hi)
        np.testing.assert_array_equal(out[1], lo)

    def test_dac_rounds_half_away_from_zero(self):
        q = np.zeros((64, 128), np.float32)
        q[0, 0] = 0.5
        q[1, 0] = -0.5
        g = np.zeros((128, 128), np.float32)
        g[:, 0] = 1.0
        out = run_kernel(q, g, IDEAL_LSB, IDEAL_QMAX)
        assert out[0, 0] == 1.0 and out[1, 0] == -1.0


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    rt=st.integers(1, 4),
    ct=st.integers(1, 4),
    adc_bits=st.integers(1, 6),
    clip_exp=st.integers(5, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernel_equals_oracle(n, rt, ct, adc_bits, clip_exp, seed):
    """Hypothesis sweep over packing factor, tile counts, ADC width and
    full-scale: the Pallas kernel must equal the oracle bit-exactly."""
    rng = np.random.default_rng(seed)
    b = 64
    r, c = rt * ARRAY_DIM, ct * ARRAY_DIM
    q = rand_packed(rng, (b, c), n)
    g = rand_packed(rng, (r, c), n)
    lsb, qmax = adc_params(adc_bits, float(2**clip_exp))
    np.testing.assert_array_equal(
        run_kernel(q, g, lsb, qmax), run_oracle(q, g, lsb, qmax)
    )


class TestShapeValidation:
    def test_rejects_mismatched_c(self):
        with pytest.raises(ValueError, match="queries C"):
            imc_mvm(
                jnp.zeros((64, 128)), jnp.zeros((128, 256)), _scalar(1.0), _scalar(1.0)
            )

    def test_rejects_non_tile_multiple(self):
        with pytest.raises(ValueError, match="multiples"):
            imc_mvm(
                jnp.zeros((64, 130)), jnp.zeros((128, 130)), _scalar(1.0), _scalar(1.0)
            )

    def test_adc_params_rejects_bad_bits(self):
        with pytest.raises(ValueError):
            adc_params(0, 64.0)
