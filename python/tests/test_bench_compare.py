"""Tests for `python/tools/bench_compare.py` (the serving-bench
regression gate): regression / no-regression / sentinel-skip /
dropped-record behavior, plus the parse-error and tiny-mismatch paths,
the `BENCH_drift.json` shape (accuracy fields compared absolutely,
records keyed by (section, threads, age_seconds, refresh)), and the
`BENCH_frontdoor.json` shape (records additionally keyed by coalescing
`policy`, `qps_served` throughput, and inverted-direction latency
percentile fields in logical ticks), and the `BENCH_remote.json` shape
(records additionally keyed by `workers` count and `chaos` mode, with
`workers` 0 rows as the in-process baseline). stdlib + pytest only.
"""

import importlib.util
import json
import os

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_spec = importlib.util.spec_from_file_location(
    "bench_compare", os.path.join(REPO_ROOT, "python", "tools", "bench_compare.py")
)
bc = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bc)


def record(section, threads, gathered, segmented=None, tiny=False, **extra):
    rec = {"section": section, "threads": threads, "qps_gathered": gathered, "tiny": tiny}
    if segmented is not None:
        rec["qps_segmented"] = segmented
    rec.update(extra)
    return rec


def write(tmp_path, name, records):
    p = tmp_path / name
    p.write_text(json.dumps(records), encoding="utf-8")
    return str(p)


def compare(tmp_path, baseline, current, extra_args=()):
    b = write(tmp_path, "baseline.json", baseline)
    c = write(tmp_path, "current.json", current)
    return bc.main([b, c, *extra_args])


def test_no_regression_passes(tmp_path, capsys):
    base = [record("batch_scoring", 4, 100.0, 120.0)]
    curr = [record("batch_scoring", 4, 101.0, 125.0)]
    assert compare(tmp_path, base, curr) == 0
    out = capsys.readouterr().out
    assert "ok" in out and "FAIL" not in out


def test_regression_beyond_threshold_fails(tmp_path, capsys):
    base = [record("batch_scoring", 4, 100.0)]
    curr = [record("batch_scoring", 4, 80.0)]  # -20% < default 15% budget
    assert compare(tmp_path, base, curr) == 1
    assert "FAIL" in capsys.readouterr().out


def test_regression_within_threshold_passes(tmp_path):
    base = [record("batch_scoring", 4, 100.0)]
    curr = [record("batch_scoring", 4, 90.0)]  # -10% within default 15%
    assert compare(tmp_path, base, curr) == 0
    # ...but a tightened budget catches it.
    assert compare(tmp_path, base, curr, ["--max-regression", "0.05"]) == 1


def test_sentinel_baseline_skipped_not_failed(tmp_path, capsys):
    # A schema-only baseline (qps 0.0) committed from a toolchain-less
    # machine degrades to a schema check.
    base = [record("single_query", 1, 0.0)]
    curr = [record("single_query", 1, 5000.0)]
    assert compare(tmp_path, base, curr) == 0
    out = capsys.readouterr().out
    assert "skip" in out and "sentinel" in out


def test_current_unmeasured_is_a_failure(tmp_path, capsys):
    # The inverse direction is NOT a sentinel: losing a real measurement
    # must fail.
    base = [record("single_query", 1, 5000.0)]
    curr = [record("single_query", 1, 0.0)]
    assert compare(tmp_path, base, curr) == 1
    assert "unmeasured" in capsys.readouterr().err


def test_dropped_record_is_a_failure(tmp_path, capsys):
    base = [record("batch_scoring", 4, 100.0), record("single_query", 1, 900.0)]
    curr = [record("batch_scoring", 4, 100.0)]
    assert compare(tmp_path, base, curr) == 1
    assert "missing from current run" in capsys.readouterr().err


def test_tiny_scale_mismatch_skipped(tmp_path, capsys):
    base = [record("batch_scoring", 4, 100.0, tiny=False)]
    curr = [record("batch_scoring", 4, 2.0, tiny=True)]  # smoke run, incomparable
    assert compare(tmp_path, base, curr) == 0
    assert "scale mismatch" in capsys.readouterr().out


def test_meta_records_ignored(tmp_path):
    meta = {"section": "meta", "git": "abc123", "host": "ci"}
    base = [meta, record("batch_scoring", 4, 100.0)]
    curr = [meta, record("batch_scoring", 4, 100.0)]
    assert compare(tmp_path, base, curr) == 0


def test_records_matched_by_section_and_threads(tmp_path):
    # Same section at different thread counts are distinct measurements.
    base = [record("batch_scoring", 1, 50.0), record("batch_scoring", 4, 100.0)]
    curr = [record("batch_scoring", 1, 50.0), record("batch_scoring", 4, 50.0)]
    assert compare(tmp_path, base, curr) == 1


def test_self_compare_is_clean(tmp_path):
    recs = [record("batch_scoring", 4, 100.0, 120.0), record("single_query", 1, 900.0)]
    assert compare(tmp_path, recs, recs) == 0


def test_parse_error_exits_2(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("not json", encoding="utf-8")
    good = write(tmp_path, "good.json", [])
    with pytest.raises(SystemExit) as exc:
        bc.main([str(bad), good])
    assert exc.value.code == 2


def test_non_array_json_exits_2(tmp_path):
    notarray = write(tmp_path, "obj.json", {})
    good = write(tmp_path, "good.json", [])
    with pytest.raises(SystemExit) as exc:
        bc.main([notarray, good])
    assert exc.value.code == 2


def test_max_regression_bounds_enforced(tmp_path):
    b = write(tmp_path, "b.json", [])
    c = write(tmp_path, "c.json", [])
    with pytest.raises(SystemExit):
        bc.main([b, c, "--max-regression", "1.5"])


def test_committed_baseline_self_compares_clean():
    baseline = os.path.join(REPO_ROOT, "BENCH_serving.json")
    assert bc.main([baseline, baseline]) == 0


# ---- BENCH_drift.json shape: accuracy fields + (age, refresh) keys ---------


def drift_record(age, refresh, accuracy, qps=10.0, tiny=False):
    return {
        "section": "drift_serving",
        "threads": 1,
        "age_seconds": age,
        "refresh": refresh,
        "accuracy": accuracy,
        "qps_segmented": qps,
        "tiny": tiny,
    }


def test_accuracy_drop_beyond_tolerance_fails(tmp_path, capsys):
    base = [drift_record(1e9, False, 0.90)]
    curr = [drift_record(1e9, False, 0.85)]  # -0.05 < default 0.02 tolerance
    assert compare(tmp_path, base, curr) == 1
    assert "below baseline" in capsys.readouterr().err


def test_accuracy_drop_within_tolerance_passes(tmp_path):
    base = [drift_record(1e9, False, 0.90)]
    curr = [drift_record(1e9, False, 0.89)]  # -0.01 within default 0.02
    assert compare(tmp_path, base, curr) == 0
    # ...but a zero tolerance catches any drop.
    assert compare(tmp_path, base, curr, ["--accuracy-tolerance", "0.0"]) == 1


def test_accuracy_improvement_passes(tmp_path, capsys):
    base = [drift_record(1e12, True, 0.70)]
    curr = [drift_record(1e12, True, 0.95)]
    assert compare(tmp_path, base, curr) == 0
    assert "ok" in capsys.readouterr().out


def test_zero_accuracy_baseline_is_a_real_measurement(tmp_path, capsys):
    # Unlike qps, accuracy 0.0 is a legitimate value: only negative
    # baselines are sentinels.
    base = [drift_record(1e12, False, 0.0)]
    curr = [drift_record(1e12, False, 0.0)]
    assert compare(tmp_path, base, curr) == 0
    assert "accuracy: 0.000 -> 0.000" in capsys.readouterr().out


def test_negative_accuracy_baseline_is_a_sentinel(tmp_path, capsys):
    base = [drift_record(1e12, False, -1.0, qps=0.0)]
    curr = [drift_record(1e12, False, 0.42)]
    assert compare(tmp_path, base, curr) == 0
    out = capsys.readouterr().out
    assert "skip" in out and "sentinel" in out


def test_negative_current_accuracy_is_a_failure(tmp_path, capsys):
    base = [drift_record(1e12, False, 0.42)]
    curr = [drift_record(1e12, False, -1.0)]
    assert compare(tmp_path, base, curr) == 1
    assert "unmeasured" in capsys.readouterr().err


def test_drift_records_matched_by_age_and_refresh(tmp_path, capsys):
    # The same section/threads at different (age, refresh) points are
    # distinct measurements; dropping one of them must fail.
    base = [
        drift_record(0.0, False, 0.95),
        drift_record(0.0, True, 0.95),
        drift_record(1e12, False, 0.60),
        drift_record(1e12, True, 0.95),
    ]
    curr = [r for r in base if not (r["age_seconds"] == 1e12 and r["refresh"])]
    assert compare(tmp_path, base, base) == 0
    assert compare(tmp_path, base, curr) == 1
    assert "refresh=on" in capsys.readouterr().err


def test_committed_drift_baseline_self_compares_clean():
    baseline = os.path.join(REPO_ROOT, "BENCH_drift.json")
    assert bc.main([baseline, baseline]) == 0


# ---- BENCH_frontdoor.json shape: policy keys + latency fields ---------------


def frontdoor_record(policy, threads, qps, p50=2.0, p99=8.0, tiny=False):
    return {
        "section": "serving_frontdoor",
        "policy": policy,
        "threads": threads,
        "qps_served": qps,
        "p50_wait_ticks": p50,
        "p99_wait_ticks": p99,
        "tiny": tiny,
    }


def test_frontdoor_records_matched_by_policy(tmp_path, capsys):
    # The same section/threads under different coalescing policies are
    # distinct measurements; dropping one of them must fail.
    base = [
        frontdoor_record("off", 4, 100.0),
        frontdoor_record("size", 4, 300.0),
        frontdoor_record("deadline", 4, 280.0),
    ]
    curr = [r for r in base if r["policy"] != "size"]
    assert compare(tmp_path, base, base) == 0
    assert compare(tmp_path, base, curr) == 1
    assert "policy=size" in capsys.readouterr().err


def test_qps_served_regression_fails(tmp_path, capsys):
    base = [frontdoor_record("size", 4, 300.0)]
    curr = [frontdoor_record("size", 4, 200.0)]  # -33% < default 15% budget
    assert compare(tmp_path, base, curr) == 1
    assert "qps_served" in capsys.readouterr().err


def test_latency_growth_beyond_tolerance_fails(tmp_path, capsys):
    # Latency direction is inverted: higher ticks are worse.
    base = [frontdoor_record("deadline", 4, 280.0, p99=8.0)]
    curr = [frontdoor_record("deadline", 4, 280.0, p99=12.0)]  # +50% > 25%
    assert compare(tmp_path, base, curr) == 1
    assert "p99_wait_ticks" in capsys.readouterr().err


def test_latency_growth_within_tolerance_passes(tmp_path):
    base = [frontdoor_record("deadline", 4, 280.0, p99=8.0)]
    curr = [frontdoor_record("deadline", 4, 280.0, p99=9.0)]  # +12.5% < 25%
    assert compare(tmp_path, base, curr) == 0
    # ...but a zero tolerance catches any growth.
    assert compare(tmp_path, base, curr, ["--latency-tolerance", "0.0"]) == 1


def test_latency_improvement_passes(tmp_path, capsys):
    base = [frontdoor_record("size", 4, 300.0, p50=5.0, p99=20.0)]
    curr = [frontdoor_record("size", 4, 310.0, p50=1.0, p99=4.0)]
    assert compare(tmp_path, base, curr) == 0
    assert "FAIL" not in capsys.readouterr().out


def test_zero_latency_baseline_is_a_real_measurement(tmp_path, capsys):
    # A burst trace under a size trigger waits 0 ticks — that is a
    # measurement, not a sentinel; only negative values are sentinels.
    base = [frontdoor_record("size", 1, 50.0, p50=0.0, p99=0.0)]
    curr = [frontdoor_record("size", 1, 50.0, p50=0.0, p99=0.0)]
    assert compare(tmp_path, base, curr) == 0
    assert "p50_wait_ticks: 0.0 -> 0.0" in capsys.readouterr().out


def test_negative_latency_baseline_is_a_sentinel(tmp_path, capsys):
    base = [frontdoor_record("off", 1, 0.0, p50=-1.0, p99=-1.0)]
    curr = [frontdoor_record("off", 1, 120.0, p50=0.0, p99=0.0)]
    assert compare(tmp_path, base, curr) == 0
    out = capsys.readouterr().out
    assert "skip" in out and "sentinel" in out


def test_negative_current_latency_is_a_failure(tmp_path, capsys):
    base = [frontdoor_record("off", 1, 120.0, p50=0.0, p99=0.0)]
    curr = [frontdoor_record("off", 1, 120.0, p50=-1.0, p99=-1.0)]
    assert compare(tmp_path, base, curr) == 1
    assert "unmeasured" in capsys.readouterr().err


def test_latency_tolerance_bounds_enforced(tmp_path):
    b = write(tmp_path, "b.json", [])
    c = write(tmp_path, "c.json", [])
    with pytest.raises(SystemExit):
        bc.main([b, c, "--latency-tolerance", "-0.1"])


def test_committed_frontdoor_baseline_self_compares_clean():
    baseline = os.path.join(REPO_ROOT, "BENCH_frontdoor.json")
    assert bc.main([baseline, baseline]) == 0


# ---- BENCH_remote.json shape: (workers, chaos) keys -------------------------


def remote_record(workers, chaos, qps, tiny=False):
    return {
        "section": "serving_remote",
        "workers": workers,
        "chaos": chaos,
        "requests": 96,
        "qps_served": qps,
        "retries": 0,
        "respawns": 0,
        "worst_coverage": 1.0,
        "tiny": tiny,
    }


def test_remote_records_matched_by_workers_and_chaos(tmp_path, capsys):
    # The same section under different worker counts / chaos modes are
    # distinct measurements; dropping one of them must fail.
    base = [
        remote_record(0, "in-process-x2", 400.0),
        remote_record(2, "none", 300.0),
        remote_record(2, "kill", 250.0),
        remote_record(2, "degrade", 350.0),
        remote_record(4, "none", 280.0),
    ]
    curr = [r for r in base if not (r["workers"] == 2 and r["chaos"] == "kill")]
    assert compare(tmp_path, base, base) == 0
    assert compare(tmp_path, base, curr) == 1
    err = capsys.readouterr().err
    assert "workers=2" in err and "chaos=kill" in err


def test_remote_qps_regression_fails(tmp_path, capsys):
    base = [remote_record(2, "none", 300.0)]
    curr = [remote_record(2, "none", 200.0)]  # -33% < default 15% budget
    assert compare(tmp_path, base, curr) == 1
    assert "qps_served" in capsys.readouterr().err


def test_remote_workers_zero_is_a_distinct_baseline_row(tmp_path):
    # workers=0 (in-process) and workers=2 (remote) must never collide
    # into one key even when their chaos tags were equal.
    base = [remote_record(0, "none", 400.0), remote_record(2, "none", 300.0)]
    curr = [remote_record(0, "none", 400.0), remote_record(2, "none", 290.0)]
    assert compare(tmp_path, base, curr) == 0
    curr = [remote_record(0, "none", 400.0)]
    assert compare(tmp_path, base, curr) == 1


def test_remote_sentinel_baseline_skipped_not_failed(tmp_path, capsys):
    base = [remote_record(2, "degrade", 0.0)]
    curr = [remote_record(2, "degrade", 123.0)]
    assert compare(tmp_path, base, curr) == 0
    out = capsys.readouterr().out
    assert "skip" in out and "sentinel" in out


def test_committed_remote_baseline_self_compares_clean():
    baseline = os.path.join(REPO_ROOT, "BENCH_remote.json")
    assert bc.main([baseline, baseline]) == 0
