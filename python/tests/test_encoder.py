"""L2 ID-level HD encoder (paper Eq. 1) vs oracle + HD-space properties."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_hvs(rng, f, m, d):
    id_hvs = rng.choice([-1.0, 1.0], size=(f, d)).astype(np.float32)
    level_hvs = rng.choice([-1.0, 1.0], size=(m, d)).astype(np.float32)
    return id_hvs, level_hvs


class TestEncoder:
    @pytest.mark.parametrize("b,f,m,d", [(8, 32, 16, 256), (64, 512, 64, 2048)])
    def test_scan_encoder_matches_oracle(self, b, f, m, d):
        rng = np.random.default_rng(b + f)
        id_hvs, level_hvs = make_hvs(rng, f, m, d)
        levels = rng.integers(0, m, size=(b, f)).astype(np.int32)
        out = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        orc = np.asarray(ref.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        np.testing.assert_array_equal(out, orc)

    def test_output_is_bipolar(self):
        rng = np.random.default_rng(0)
        id_hvs, level_hvs = make_hvs(rng, 64, 16, 512)
        levels = rng.integers(0, 16, size=(8, 64)).astype(np.int32)
        out = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        assert set(np.unique(out)) <= {-1.0, 1.0}

    def test_identical_inputs_identical_hvs(self):
        rng = np.random.default_rng(1)
        id_hvs, level_hvs = make_hvs(rng, 64, 16, 512)
        lv = rng.integers(0, 16, size=(1, 64)).astype(np.int32)
        levels = np.repeat(lv, 4, axis=0)
        out = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        for i in range(1, 4):
            np.testing.assert_array_equal(out[0], out[i])

    def test_similar_spectra_closer_than_random(self):
        """The defining HD property: near-identical level vectors encode to
        near-identical HVs; unrelated ones land ~orthogonal (dot ~ 0)."""
        rng = np.random.default_rng(2)
        f, m, d = 128, 32, 2048
        id_hvs, level_hvs = make_hvs(rng, f, m, d)
        base = rng.integers(0, m, size=f)
        near = base.copy()
        idx = rng.choice(f, size=5, replace=False)
        near[idx] = rng.integers(0, m, size=5)
        far = rng.integers(0, m, size=f)
        levels = np.stack([base, near, far]).astype(np.int32)
        hv = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        sim_near = hv[0] @ hv[1] / d
        sim_far = hv[0] @ hv[2] / d
        assert sim_near > 0.5
        assert abs(sim_far) < 0.2
        assert sim_near > sim_far

    def test_sign_tie_rule_is_plus_one(self):
        # With constructed cancelling contributions, ties hit 0; the
        # convention (shared with rust/src/hd) must map 0 -> +1.
        id_hvs = np.ones((2, 4), np.float32)
        level_hvs = np.stack([np.zeros(4), np.ones(4), -np.ones(4)]).astype(np.float32)
        levels = np.array([[1, 2]], np.int32)  # +1 + (-1) = 0 everywhere
        out = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
        np.testing.assert_array_equal(out, 1.0)

    def test_level_zero_is_inert(self):
        # Level 0 marks an empty bin: it must contribute nothing, whatever
        # LV[0] contains.
        rng = np.random.default_rng(5)
        id_hvs, level_hvs = make_hvs(rng, 16, 8, 256)
        levels = np.zeros((2, 16), np.int32)
        levels[1, 3] = 4  # one peak in spectrum 1
        out = np.asarray(
            model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs))
        )
        # All-empty spectrum: acc == 0 -> +1 everywhere (tie rule).
        np.testing.assert_array_equal(out[0], 1.0)
        # Single peak: HV = sign(LV[4] * ID[3]) = the elementwise product.
        np.testing.assert_array_equal(out[1], level_hvs[4] * id_hvs[3])


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 16),
    f=st.integers(1, 64),
    m=st.integers(2, 32),
    d=st.sampled_from([64, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_encoder_matches_oracle(b, f, m, d, seed):
    rng = np.random.default_rng(seed)
    id_hvs, level_hvs = make_hvs(rng, f, m, d)
    levels = rng.integers(0, m, size=(b, f)).astype(np.int32)
    out = np.asarray(model.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
    orc = np.asarray(ref.encode(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs)))
    np.testing.assert_array_equal(out, orc)


class TestEncodePack:
    @pytest.mark.parametrize("n", [1, 2, 3])
    def test_encode_pack_matches_oracle(self, n):
        rng = np.random.default_rng(n)
        id_hvs, level_hvs = make_hvs(rng, 128, 32, 1024)
        levels = rng.integers(0, 32, size=(16, 128)).astype(np.int32)
        out = np.asarray(
            model.encode_pack(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs), n)
        )
        orc = np.asarray(
            ref.encode_pack(jnp.array(levels), jnp.array(id_hvs), jnp.array(level_hvs), n)
        )
        np.testing.assert_array_equal(out, orc)
