"""Float32 model of the rust cache-blocked segmented MVM kernel.

`rust/src/array/transfer.rs::imc_mvm_blocked_into` claims bit-identity
with the naive reference transfer function (`imc_mvm_ref`) because the
blocking only reorders *which output* is computed next, never the
accumulation order inside one output. This test reproduces both loop
structures in numpy float32 — including the DAC round/clip, the per-tile
ADC quantization, and the f32 partial-sum ordering — and asserts exact
(bitwise) equality over randomized ragged-segment workloads.

numpy-only (no jax): runs wherever the other kernel tests run.
"""

import numpy as np

ARRAY_DIM = 128
QUERY_BLOCK = 16  # must match transfer.rs::QUERY_BLOCK


def dac_quantize(x):
    # round half away from zero, clip to the 3-bit DAC range [-4, 3]
    q = np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5)).astype(np.float32)
    return np.clip(q, -4.0, 3.0).astype(np.float32)


def adc_quantize(s, lsb, qmax):
    v = s / np.float32(lsb)
    v = np.where(v >= 0, np.floor(v + 0.5), np.ceil(v - 0.5)).astype(np.float32)
    v = np.clip(v, -(qmax + 1.0), qmax).astype(np.float32)
    return (v * np.float32(lsb)).astype(np.float32)


def imc_mvm_ref(queries, refs, b, r, c, lsb, qmax):
    """The naive reference loop nest: per (query, row), tiles in order."""
    dacq = dac_quantize(queries)
    tiles = c // ARRAY_DIM
    out = np.zeros(b * r, dtype=np.float32)
    for bi in range(b):
        qrow = dacq[bi * c : (bi + 1) * c]
        for ri in range(r):
            grow = refs[ri * c : (ri + 1) * c]
            acc = np.float32(0)
            for t in range(tiles):
                lo = t * ARRAY_DIM
                part = np.float32(0)
                for k in range(lo, lo + ARRAY_DIM):
                    part = np.float32(part + np.float32(qrow[k] * grow[k]))
                acc = np.float32(acc + adc_quantize(part, lsb, qmax))
            out[bi * r + ri] = acc
    return out


def imc_mvm_blocked(queries, panel, segments, b, c, lsb, qmax):
    """The blocked loop nest from transfer.rs, transcribed 1:1."""
    dacq = dac_quantize(queries)
    tiles = c // ARRAY_DIM
    r = sum(e - s for (s, e) in segments)
    out = np.zeros(b * r, dtype=np.float32)
    acc = np.zeros(QUERY_BLOCK * ARRAY_DIM, dtype=np.float32)
    q0 = 0
    while q0 < b:
        qn = min(QUERY_BLOCK, b - q0)
        oc = 0
        for (seg_s, seg_e) in segments:
            p0 = seg_s
            while p0 < seg_e:
                pn = min(ARRAY_DIM, seg_e - p0)
                acc[: qn * pn] = 0
                for t in range(tiles):
                    lo = t * ARRAY_DIM
                    for qi in range(qn):
                        qoff = (q0 + qi) * c + lo
                        for pi in range(pn):
                            goff = (p0 + pi) * c + lo
                            part = np.float32(0)
                            for k in range(ARRAY_DIM):
                                part = np.float32(
                                    part + np.float32(dacq[qoff + k] * panel[goff + k])
                                )
                            acc[qi * pn + pi] = np.float32(
                                acc[qi * pn + pi] + adc_quantize(part, lsb, qmax)
                            )
                for qi in range(qn):
                    ooff = (q0 + qi) * r + oc
                    out[ooff : ooff + pn] = acc[qi * pn : (qi + 1) * pn]
                oc += pn
                p0 += pn
        q0 += qn
    return out


def gather(panel, segments, c):
    parts = [panel[s * c : e * c] for (s, e) in segments]
    return np.concatenate(parts) if parts else np.zeros(0, dtype=np.float32)


def test_blocked_bit_identical_to_gathered_ref():
    rng = np.random.default_rng(0x5EC)
    for trial in range(8):
        c = ARRAY_DIM * int(rng.integers(1, 3))
        panel_rows = int(rng.integers(1, 180))
        b = int(rng.integers(1, QUERY_BLOCK + 5))  # crosses the block edge
        panel = rng.integers(-3, 4, size=panel_rows * c).astype(np.float32)
        # Non-integer conductances exercise f32 rounding in the dot chain.
        panel += rng.normal(0, 0.05, size=panel.shape).astype(np.float32)
        queries = rng.integers(-3, 4, size=b * c).astype(np.float32)

        segments = []
        for _ in range(int(rng.integers(0, 5))):
            a, z = sorted(rng.integers(0, panel_rows + 1, size=2).tolist())
            segments.append((int(a), int(z)))
        segments.append((0, 0))  # empty segment
        single = int(rng.integers(0, panel_rows))
        segments.append((single, single + 1))  # single-row bucket
        if panel_rows > ARRAY_DIM + 5:
            segments.append((ARRAY_DIM - 3, ARRAY_DIM + 5))  # tile straddle

        lsb, qmax = 16.0, 31.0
        r = sum(e - s for (s, e) in segments)
        want = imc_mvm_ref(queries, gather(panel, segments, c), b, r, c, lsb, qmax)
        got = imc_mvm_blocked(queries, panel, segments, b, c, lsb, qmax)
        assert got.tobytes() == want.tobytes(), f"trial {trial}: blocked != ref"
