"""Float32 model of the rust lane-ordered MVM kernels (PR 6).

`rust/src/array/transfer.rs` defines the canonical in-tile accumulation
order: eight `k % 8` partial-sum lanes (each accumulated in ascending
`k`) reduced by the fixed binary tree
`((l0 + l4) + (l2 + l6)) + ((l1 + l5) + (l3 + l7))`. The scalar oracle
(`imc_mvm_ref`) codes the lanes lane-major; the fast blocked kernel
(`imc_mvm_blocked_into` via `lane_tile_dot`) codes them chunk-major so
the autovectorizer emits SIMD. This test reproduces both codings in
numpy float32 — including the DAC round/clip, the per-tile ADC
quantization, and the exact f32 partial-sum ordering — and asserts:

* the two codings are bit-identical (each lane performs the identical
  f32 add sequence either way);
* the blocked/segmented loop nest equals the gathered reference nest
  bitwise over randomized ragged-segment workloads with non-integer
  conductances (integer data is exact under *any* association order and
  would mask a reassociation bug);
* hoisting the DAC out of the kernel (the engine's `ScoreScratch`
  optimization) is score-neutral, because the DAC is idempotent on its
  own output;
* the pinned f32 bit patterns asserted by the rust regression test
  (`lane_order_pinned_bits`) are exactly what this model computes for
  the same hand-built tile — the constants' provenance.

numpy-only (no jax): runs wherever the other kernel tests run.
"""

import numpy as np

ARRAY_DIM = 128
MVM_LANES = 8  # must match transfer.rs::MVM_LANES
QUERY_BLOCK = 16  # must match transfer.rs::QUERY_BLOCK


def dac_quantize(x):
    # round half away from zero, clip to the 3-bit DAC range [-4, 3]
    q = np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5)).astype(np.float32)
    return np.clip(q, -4.0, 3.0).astype(np.float32)


def adc_quantize(s, lsb, qmax):
    v = s / np.float32(lsb)
    v = np.where(v >= 0, np.floor(v + 0.5), np.ceil(v - 0.5)).astype(np.float32)
    v = np.clip(v, -(qmax + 1.0), qmax).astype(np.float32)
    return (v * np.float32(lsb)).astype(np.float32)


def lane_tree_reduce(lanes):
    a = np.float32(np.float32(lanes[0] + lanes[4]) + np.float32(lanes[2] + lanes[6]))
    b = np.float32(np.float32(lanes[1] + lanes[5]) + np.float32(lanes[3] + lanes[7]))
    return np.float32(a + b)


def lane_tile_dot_lane_major(q, g):
    """Oracle coding (imc_mvm_ref): one scalar loop per lane."""
    lanes = np.zeros(MVM_LANES, dtype=np.float32)
    for l in range(MVM_LANES):
        for k in range(l, ARRAY_DIM, MVM_LANES):
            lanes[l] = np.float32(lanes[l] + np.float32(q[k] * g[k]))
    return lane_tree_reduce(lanes)


def lane_tile_dot_chunk_major(q, g):
    """Fast-kernel coding (lane_tile_dot): walk 16 chunks of 8, all 8
    lane accumulators in flight — the autovectorizable shape."""
    lanes = np.zeros(MVM_LANES, dtype=np.float32)
    for i in range(ARRAY_DIM // MVM_LANES):
        for j in range(MVM_LANES):
            k = i * MVM_LANES + j
            lanes[j] = np.float32(lanes[j] + np.float32(q[k] * g[k]))
    return lane_tree_reduce(lanes)


def imc_mvm_ref(queries, refs, b, r, c, lsb, qmax):
    """The reference loop nest: per (query, row), tiles in order, each
    tile reduced in the canonical lane order (lane-major coding)."""
    dacq = dac_quantize(queries)
    tiles = c // ARRAY_DIM
    out = np.zeros(b * r, dtype=np.float32)
    for bi in range(b):
        qrow = dacq[bi * c : (bi + 1) * c]
        for ri in range(r):
            grow = refs[ri * c : (ri + 1) * c]
            acc = np.float32(0)
            for t in range(tiles):
                lo = t * ARRAY_DIM
                part = lane_tile_dot_lane_major(qrow[lo : lo + ARRAY_DIM], grow[lo : lo + ARRAY_DIM])
                acc = np.float32(acc + adc_quantize(part, lsb, qmax))
            out[bi * r + ri] = acc
    return out


def imc_mvm_blocked_dacq(dacq, panel, segments, b, c, lsb, qmax):
    """The blocked loop nest from transfer.rs (pre-quantized queries),
    transcribed 1:1 with the chunk-major tile dot."""
    tiles = c // ARRAY_DIM
    r = sum(e - s for (s, e) in segments)
    out = np.zeros(b * r, dtype=np.float32)
    acc = np.zeros(QUERY_BLOCK * ARRAY_DIM, dtype=np.float32)
    q0 = 0
    while q0 < b:
        qn = min(QUERY_BLOCK, b - q0)
        oc = 0
        for (seg_s, seg_e) in segments:
            p0 = seg_s
            while p0 < seg_e:
                pn = min(ARRAY_DIM, seg_e - p0)
                acc[: qn * pn] = 0
                for t in range(tiles):
                    lo = t * ARRAY_DIM
                    for qi in range(qn):
                        qoff = (q0 + qi) * c + lo
                        for pi in range(pn):
                            goff = (p0 + pi) * c + lo
                            part = lane_tile_dot_chunk_major(
                                dacq[qoff : qoff + ARRAY_DIM],
                                panel[goff : goff + ARRAY_DIM],
                            )
                            acc[qi * pn + pi] = np.float32(
                                acc[qi * pn + pi] + adc_quantize(part, lsb, qmax)
                            )
                for qi in range(qn):
                    ooff = (q0 + qi) * r + oc
                    out[ooff : ooff + pn] = acc[qi * pn : (qi + 1) * pn]
                oc += pn
                p0 += pn
        q0 += qn
    return out


def imc_mvm_blocked(queries, panel, segments, b, c, lsb, qmax):
    return imc_mvm_blocked_dacq(dac_quantize(queries), panel, segments, b, c, lsb, qmax)


def gather(panel, segments, c):
    parts = [panel[s * c : e * c] for (s, e) in segments]
    return np.concatenate(parts) if parts else np.zeros(0, dtype=np.float32)


def pinned_tile():
    """The hand-built reassociation-sensitive tile shared with the rust
    `lane_order_pinned_bits` test: integer DAC levels against non-dyadic
    conductances (so f32 rounding is live and the association order shows
    in the result bits)."""
    q = np.array([((k * 7) % 8) - 4 for k in range(ARRAY_DIM)], dtype=np.float32)
    g = np.array(
        [np.float32(np.float32(k - 64) / np.float32(100.0)) for k in range(ARRAY_DIM)],
        dtype=np.float32,
    )
    return q, g


def test_lane_codings_bit_identical():
    rng = np.random.default_rng(7)
    for trial in range(50):
        q = rng.integers(-4, 4, ARRAY_DIM).astype(np.float32)
        g = (
            rng.integers(-3, 4, ARRAY_DIM).astype(np.float32)
            + rng.normal(0, 0.05, ARRAY_DIM).astype(np.float32)
        )
        a = lane_tile_dot_lane_major(q, g)
        b = lane_tile_dot_chunk_major(q, g)
        assert a.tobytes() == b.tobytes(), f"trial {trial}: codings disagree"


def test_pinned_bits_match_rust_regression_constants():
    q, g = pinned_tile()
    lane = lane_tile_dot_chunk_major(q, g)
    assert lane.tobytes() == lane_tile_dot_lane_major(q, g).tobytes()
    # The exact constants asserted by transfer.rs::lane_order_pinned_bits.
    assert int(lane.view(np.uint32)) == 0xBFF5C288, hex(int(lane.view(np.uint32)))
    # The pre-PR-6 ascending-k order lands on different bits — the tile
    # really is sensitive to reassociation.
    asc = np.float32(0)
    for k in range(ARRAY_DIM):
        asc = np.float32(asc + np.float32(q[k] * g[k]))
    assert int(asc.view(np.uint32)) == 0xBFF5C290, hex(int(asc.view(np.uint32)))


def test_blocked_bit_identical_to_gathered_ref():
    rng = np.random.default_rng(0x5EC)
    for trial in range(8):
        c = ARRAY_DIM * int(rng.integers(1, 3))
        panel_rows = int(rng.integers(1, 180))
        b = int(rng.integers(1, QUERY_BLOCK + 5))  # crosses the block edge
        panel = rng.integers(-3, 4, size=panel_rows * c).astype(np.float32)
        # Non-integer conductances exercise f32 rounding in the dot chain.
        panel += rng.normal(0, 0.05, size=panel.shape).astype(np.float32)
        queries = rng.integers(-3, 4, size=b * c).astype(np.float32)

        segments = []
        for _ in range(int(rng.integers(0, 5))):
            a, z = sorted(rng.integers(0, panel_rows + 1, size=2).tolist())
            segments.append((int(a), int(z)))
        segments.append((0, 0))  # empty segment
        single = int(rng.integers(0, panel_rows))
        segments.append((single, single + 1))  # single-row bucket
        if panel_rows > ARRAY_DIM + 5:
            segments.append((ARRAY_DIM - 3, ARRAY_DIM + 5))  # tile straddle

        lsb, qmax = 16.0, 31.0
        r = sum(e - s for (s, e) in segments)
        want = imc_mvm_ref(queries, gather(panel, segments, c), b, r, c, lsb, qmax)
        got = imc_mvm_blocked(queries, panel, segments, b, c, lsb, qmax)
        assert got.tobytes() == want.tobytes(), f"trial {trial}: blocked != ref"


def test_dac_hoisting_is_score_neutral():
    # The engine quantizes each batch once (ScoreScratch.dacq) and marks
    # jobs dac_applied; because dac_quantize(dac_quantize(x)) ==
    # dac_quantize(x), pre-quantized scoring is bit-identical.
    rng = np.random.default_rng(0xDAC)
    c = ARRAY_DIM * 2
    b, panel_rows = 5, 90
    queries = (rng.integers(-40, 41, size=b * c) / 8.0).astype(np.float32)
    panel = rng.integers(-3, 4, size=panel_rows * c).astype(np.float32)
    panel += rng.normal(0, 0.05, size=panel.shape).astype(np.float32)
    segments = [(0, 40), (50, 51), (60, 60), (70, 90)]
    lsb, qmax = 16.0, 31.0

    dacq = dac_quantize(queries)
    # Numeric idempotence (this model's np.where flips -0.0 to +0.0 on the
    # second pass — rust's f32::round/clamp preserve the zero sign and are
    # bitwise idempotent — but the sign of zero never reaches a score:
    # +-0.0 products leave every accumulator unchanged).
    assert np.array_equal(dac_quantize(dacq), dacq), "DAC must be idempotent"
    # The property the dac_applied flag relies on: scoring pre-quantized
    # queries (hoisted path) is bit-identical to the kernel re-quantizing
    # them (un-hoisted path).
    requantized = imc_mvm_blocked(dacq, panel, segments, b, c, lsb, qmax)
    hoisted = imc_mvm_blocked_dacq(dacq, panel, segments, b, c, lsb, qmax)
    assert hoisted.tobytes() == requantized.tobytes()
    # And hoisting commutes with the full pipeline on raw (fractional)
    # queries: quantize-once-then-score == score-with-internal-quantize.
    want = imc_mvm_blocked(queries, panel, segments, b, c, lsb, qmax)
    assert hoisted.tobytes() == want.tobytes()
