"""Fixture tests for the contract linter (`python/tools/lint_contracts.py`).

Each rule is exercised with an inline Rust snippet pair — one violating,
one conforming — plus allowlist-marker handling, `--explain` output, and
a self-check that the committed tree is lint-clean. stdlib + pytest only
(no rust toolchain, no jax).
"""

import importlib.util
import os

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_spec = importlib.util.spec_from_file_location(
    "lint_contracts", os.path.join(REPO_ROOT, "python", "tools", "lint_contracts.py")
)
lint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(lint)


def write_tree(tmp_path, files):
    """Lay out {relpath-under-rust/src: text} and return the fake repo root."""
    for rel, text in files.items():
        p = tmp_path / "rust" / "src" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text, encoding="utf-8")
    return tmp_path


def rule_hits(findings, rule_id):
    return [f for f in findings if f.rule_id == rule_id]


# -------------------------------------------------------------------------
# C1-REASSOC
# -------------------------------------------------------------------------

VIOLATING_ACCUM = """\
pub fn hot_dot(xs: &[f32], ys: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (x, y) in xs.iter().zip(ys) {
        acc += x * y;
    }
    acc
}
"""

CONFORMING_LANE = """\
pub fn lane_tile_dot(xs: &[f32], ys: &[f32]) -> f32 {
    let mut lanes = [0f32; 8];
    for (k, (x, y)) in xs.iter().zip(ys).enumerate() {
        lanes[k % 8] += x * y;
    }
    lanes.iter().copied().sum::<f32>()
}
"""


def test_c1_fires_on_raw_accumulation(tmp_path):
    root = write_tree(tmp_path, {"array/kernel.rs": VIOLATING_ACCUM})
    hits = rule_hits(lint.lint_tree(root), "C1-REASSOC")
    assert len(hits) == 1
    assert hits[0].path == "array/kernel.rs"
    assert hits[0].line == 4
    assert "acc" in hits[0].message


def test_c1_blesses_lane_primitive_bodies(tmp_path):
    root = write_tree(tmp_path, {"array/kernel.rs": CONFORMING_LANE})
    assert rule_hits(lint.lint_tree(root), "C1-REASSOC") == []


def test_c1_scoped_to_kernel_dirs(tmp_path):
    # The same accumulation in coordinator/ (f64 merge math lives there)
    # is out of scope for C1.
    root = write_tree(tmp_path, {"coordinator/foo.rs": VIOLATING_ACCUM})
    assert rule_hits(lint.lint_tree(root), "C1-REASSOC") == []


def test_c1_fires_on_sum_fold_and_dot_shapes(tmp_path):
    text = """\
pub fn a(xs: &[f32]) -> f32 {
    xs.iter().copied().sum::<f32>()
}
pub fn b(xs: &[f32]) -> f32 {
    xs.iter().fold(0f32, |a, x| a + x)
}
pub fn c(xs: &[f32], ys: &[f32]) -> f32 {
    xs.iter().zip(ys).map(|(x, y)| x * y).sum()
}
"""
    root = write_tree(tmp_path, {"hd/sums.rs": text})
    hits = rule_hits(lint.lint_tree(root), "C1-REASSOC")
    assert [h.line for h in hits] == [2, 5, 8]


def test_c1_ignores_integer_sums_and_tests(tmp_path):
    text = """\
pub fn popcount_dot(xs: &[u64]) -> u32 {
    xs.iter().map(|w| w.count_ones()).sum()
}
pub fn lens(xs: &[Vec<f32>]) -> usize {
    let mut n = 0usize;
    for x in xs { n += x.len(); }
    xs.iter().map(|s| s.len()).sum()
}
#[cfg(test)]
mod tests {
    #[test]
    fn oracle() {
        let mut acc = 0f32;
        for x in [1.0f32, 2.0] { acc += x; }
        assert!(acc > 0.0);
    }
}
"""
    root = write_tree(tmp_path, {"hd/ok.rs": text})
    assert rule_hits(lint.lint_tree(root), "C1-REASSOC") == []


def test_c1_tracks_mut_slice_aliases(tmp_path):
    text = """\
pub fn blocked(n: usize) {
    let mut acc = [0f32; 64];
    let sub = &mut acc[..n];
    sub[0] += 1.0;
}
"""
    root = write_tree(tmp_path, {"backend/blk.rs": text})
    hits = rule_hits(lint.lint_tree(root), "C1-REASSOC")
    assert [h.line for h in hits] == [4]


def test_c1_marker_allows_with_reason(tmp_path):
    text = VIOLATING_ACCUM.replace(
        "        acc += x * y;",
        "        // lint: reassoc-ok (digital baseline, never bit-compared)\n"
        "        acc += x * y;",
    )
    root = write_tree(tmp_path, {"array/kernel.rs": text})
    assert rule_hits(lint.lint_tree(root), "C1-REASSOC") == []


def test_c1_marker_without_reason_is_a_finding(tmp_path):
    text = VIOLATING_ACCUM.replace(
        "        acc += x * y;",
        "        acc += x * y; // lint: reassoc-ok ()",
    )
    root = write_tree(tmp_path, {"array/kernel.rs": text})
    hits = rule_hits(lint.lint_tree(root), "C1-REASSOC")
    # Both the unexcused accumulation and the empty-reason marker fire.
    assert len(hits) == 2
    assert any("non-empty reason" in h.message for h in hits)


# -------------------------------------------------------------------------
# C2-CHARGE
# -------------------------------------------------------------------------

VIOLATING_CHARGE = """\
use crate::energy::OpCounts;

pub fn serve(ops: &mut OpCounts, n: u64) {
    ops.mvm_ops += n;
}
"""

CONFORMING_CHARGE = """\
use crate::energy::OpCounts;

pub struct GroupCharges;

impl GroupCharges {
    pub fn charge(&self, ops: &mut OpCounts, n: u64) {
        ops.mvm_ops += n;
        ops.merge_elements += n;
    }
}
"""


def test_c2_fires_on_decentralized_charge(tmp_path):
    root = write_tree(tmp_path, {"coordinator/new_path.rs": VIOLATING_CHARGE})
    hits = rule_hits(lint.lint_tree(root), "C2-CHARGE")
    assert len(hits) == 1
    assert hits[0].line == 4
    assert "mvm_ops" in hits[0].message


def test_c2_blesses_central_sites(tmp_path):
    root = write_tree(tmp_path, {"coordinator/new_path.rs": CONFORMING_CHARGE})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


def test_c2_requires_opcounts_import(tmp_path):
    # `features` / `mvm_ops` on unrelated types in a file that never
    # touches OpCounts must not fire.
    text = """\
pub struct BankCounters { pub mvm_ops: u64 }
pub fn bump(c: &mut BankCounters) { c.mvm_ops += 1; }
"""
    root = write_tree(tmp_path, {"array/bank2.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


def test_c2_receiver_heuristic_skips_non_ops_chains(tmp_path):
    text = """\
use crate::energy::OpCounts;
pub fn bump(bank: &mut Bank) {
    bank.counters.mvm_ops += 1;
}
"""
    root = write_tree(tmp_path, {"isa/bank3.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


def test_c2_whole_struct_merges_allowed(tmp_path):
    text = """\
use crate::energy::OpCounts;
pub fn fold(total: &mut OpCounts, part: &OpCounts) {
    *total += part;
}
"""
    root = write_tree(tmp_path, {"coordinator/fold.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


def test_c2_marker_allows(tmp_path):
    text = VIOLATING_CHARGE.replace(
        "    ops.mvm_ops += n;",
        "    // lint: charge-ok (single-site charge, no shard split exists)\n"
        "    ops.mvm_ops += n;",
    )
    root = write_tree(tmp_path, {"coordinator/new_path.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


# -------------------------------------------------------------------------
# C3-SYNC
# -------------------------------------------------------------------------

VIOLATING_SYNC = """\
use std::cell::RefCell;

pub struct Engine {
    cache: RefCell<Vec<f32>>,
}

pub fn stats(m: &std::sync::Mutex<u64>) -> u64 {
    *m.lock().unwrap()
}
"""

CONFORMING_SYNC = """\
use crate::util::sync::lock_unpoisoned;

pub fn stats(m: &std::sync::Mutex<u64>) -> u64 {
    *lock_unpoisoned(m, "stats")
}

pub fn maybe(m: &std::sync::Mutex<u64>) -> Option<u64> {
    m.try_lock().ok().map(|g| *g)
}
"""


def test_c3_fires_on_refcell_and_bare_lock(tmp_path):
    root = write_tree(tmp_path, {"coordinator/bad.rs": VIOLATING_SYNC})
    hits = rule_hits(lint.lint_tree(root), "C3-SYNC")
    assert [h.line for h in hits] == [1, 4, 8]
    assert any("RefCell" in h.message for h in hits)
    assert any("lock_unpoisoned" in h.message for h in hits)


def test_c3_conforming_helper_and_try_lock_pass(tmp_path):
    root = write_tree(tmp_path, {"coordinator/good.rs": CONFORMING_SYNC})
    assert rule_hits(lint.lint_tree(root), "C3-SYNC") == []


def test_c3_lock_banned_even_outside_engine_dirs(tmp_path):
    root = write_tree(tmp_path, {"telemetry/t.rs": "fn f(m: &M) { m.lock().unwrap(); }\n"})
    assert len(rule_hits(lint.lint_tree(root), "C3-SYNC")) == 1


def test_c3_util_sync_itself_exempt(tmp_path):
    root = write_tree(
        tmp_path, {"util/sync.rs": "pub fn lock_unpoisoned(m: &M) { m.lock().unwrap(); }\n"}
    )
    assert rule_hits(lint.lint_tree(root), "C3-SYNC") == []


def test_c3_refcell_in_comment_or_test_ignored(tmp_path):
    text = """\
// Engines must never hold a RefCell — see contract C3-SYNC.
pub struct Engine;
#[cfg(test)]
mod tests {
    use std::rc::Rc;
    #[test]
    fn scratch() { let _ = Rc::new(3); }
}
"""
    root = write_tree(tmp_path, {"coordinator/doc.rs": text})
    assert rule_hits(lint.lint_tree(root), "C3-SYNC") == []


def test_c3_arc_does_not_false_positive_as_rc(tmp_path):
    text = "use std::sync::Arc;\npub struct E { x: Arc<Vec<f32>> }\n"
    root = write_tree(tmp_path, {"backend/arc.rs": text})
    assert rule_hits(lint.lint_tree(root), "C3-SYNC") == []


# -------------------------------------------------------------------------
# C4-RNG
# -------------------------------------------------------------------------

VIOLATING_RNG = """\
use crate::util::Rng;

pub fn program_shard(seed: u64) -> Rng {
    Rng::new(seed ^ 0x5e)
}
"""

CONFORMING_RNG = """\
use crate::util::Rng;

pub struct ProgramContext { rng: Rng }

impl ProgramContext {
    pub fn noise_rng(seed: u64) -> Rng {
        Rng::new(seed ^ 0x5e)
    }
}
"""


def test_c4_fires_on_reseeding(tmp_path):
    root = write_tree(tmp_path, {"coordinator/shard2.rs": VIOLATING_RNG})
    hits = rule_hits(lint.lint_tree(root), "C4-RNG")
    assert [h.line for h in hits] == [4]
    assert "chained" in hits[0].message


def test_c4_blesses_program_context(tmp_path):
    root = write_tree(tmp_path, {"coordinator/ctx.rs": CONFORMING_RNG})
    assert rule_hits(lint.lint_tree(root), "C4-RNG") == []


REFRESH_RNG_IN_CONTEXT = """\
use crate::util::Rng;

pub struct ProgramContext { rng: Rng }

impl ProgramContext {
    pub fn refresh_rng(seed: u64, global_row: u64, epoch: u64) -> Rng {
        let mixed = (seed ^ 0xdf)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(global_row)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(epoch);
        Rng::new(mixed)
    }
}
"""


def test_c4_blesses_refresh_roots_inside_program_context(tmp_path):
    # The per-(global row, refresh epoch) refresh streams (PR 8) are the
    # second legal Rng root — but only inside `impl ProgramContext`.
    root = write_tree(tmp_path, {"coordinator/ctx.rs": REFRESH_RNG_IN_CONTEXT})
    assert rule_hits(lint.lint_tree(root), "C4-RNG") == []


def test_c4_fires_on_refresh_roots_outside_program_context(tmp_path):
    # The identical helper hoisted out of ProgramContext (e.g. onto the
    # engine or a free function) is a re-seeding site and must fire.
    outside = REFRESH_RNG_IN_CONTEXT.replace(
        "impl ProgramContext {", "impl RefreshScheduler {"
    )
    root = write_tree(tmp_path, {"coordinator/sched.rs": outside})
    hits = rule_hits(lint.lint_tree(root), "C4-RNG")
    assert [h.line for h in hits] == [12]


def test_c4_out_of_scope_dirs_and_tests_pass(tmp_path):
    root = write_tree(
        tmp_path,
        {
            "ms/gen.rs": VIOLATING_RNG,  # synthetic-data RNG: fine
            "coordinator/t.rs": "#[cfg(test)]\nmod tests {\n    fn f() { let r = Rng::new(1); }\n}\n",
        },
    )
    assert rule_hits(lint.lint_tree(root), "C4-RNG") == []


def test_c4_marker_allows(tmp_path):
    text = VIOLATING_RNG.replace(
        "    Rng::new(seed ^ 0x5e)",
        "    // lint: rng-ok (independent stream, never merged with scores)\n"
        "    Rng::new(seed ^ 0x5e)",
    )
    root = write_tree(tmp_path, {"coordinator/shard2.rs": text})
    assert rule_hits(lint.lint_tree(root), "C4-RNG") == []


# -------------------------------------------------------------------------
# C5-UNSAFE
# -------------------------------------------------------------------------

LIB_WITH_FORBID = "#![forbid(unsafe_code)]\npub mod array;\n"
LIB_WITHOUT_FORBID = "pub mod array;\n"


def test_c5_missing_forbid_is_a_finding(tmp_path):
    root = write_tree(tmp_path, {"lib.rs": LIB_WITHOUT_FORBID})
    hits = rule_hits(lint.lint_tree(root), "C5-UNSAFE")
    assert len(hits) == 1
    assert "forbid(unsafe_code)" in hits[0].message


def test_c5_unsafe_without_safety_comment(tmp_path):
    text = """\
pub fn peek(p: *const f32) -> f32 {
    unsafe { *p }
}
"""
    root = write_tree(tmp_path, {"lib.rs": LIB_WITH_FORBID, "array/raw.rs": text})
    hits = rule_hits(lint.lint_tree(root), "C5-UNSAFE")
    assert [h.line for h in hits] == [2]


def test_c5_safety_comment_conforms(tmp_path):
    text = """\
pub fn peek(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p is valid and aligned for reads.
    unsafe { *p }
}
"""
    root = write_tree(tmp_path, {"lib.rs": LIB_WITH_FORBID, "array/raw.rs": text})
    assert rule_hits(lint.lint_tree(root), "C5-UNSAFE") == []


def test_c5_unsafe_in_comments_ignored(tmp_path):
    text = '// this crate has no unsafe code\npub fn f() -> &\'static str { "unsafe" }\n'
    root = write_tree(tmp_path, {"lib.rs": LIB_WITH_FORBID, "hd/doc.rs": text})
    assert rule_hits(lint.lint_tree(root), "C5-UNSAFE") == []


# -------------------------------------------------------------------------
# C6-TIME
# -------------------------------------------------------------------------

VIOLATING_TIME = """\
use std::time::{Duration, SystemTime};

pub fn age(epoch: SystemTime) -> u64 {
    let now = Instant::now();
    now.elapsed().as_secs()
}
"""

CONFORMING_TICKS = """\
pub fn deadline_passed(clock: u64, start: u64, deadline_ticks: u64) -> bool {
    clock.saturating_sub(start) > deadline_ticks
}
"""


def test_c6_fires_on_std_time_instant_and_systemtime(tmp_path):
    root = write_tree(tmp_path, {"coordinator/remote/timey.rs": VIOLATING_TIME})
    hits = rule_hits(lint.lint_tree(root), "C6-TIME")
    # line 1: std::time import; line 3: SystemTime in a signature;
    # line 4: Instant::now().
    assert [h.line for h in hits] == [1, 3, 4]
    assert all("logical" in h.message for h in hits)


def test_c6_logical_tick_code_passes(tmp_path):
    root = write_tree(tmp_path, {"coordinator/remote/ticks.rs": CONFORMING_TICKS})
    assert rule_hits(lint.lint_tree(root), "C6-TIME") == []


def test_c6_applies_to_every_src_dir(tmp_path):
    # Unlike C1/C4 there is no scoped dir list — wall time is banned
    # crate-wide in non-test code, including util/ and telemetry/.
    text = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n"
    root = write_tree(tmp_path, {"util/clock.rs": text})
    assert len(rule_hits(lint.lint_tree(root), "C6-TIME")) == 1


def test_c6_test_code_may_use_wall_time(tmp_path):
    text = """\
pub fn f() -> u64 { 3 }
#[cfg(test)]
mod tests {
    use std::time::Instant;
    #[test]
    fn perf_probe() {
        let t0 = Instant::now();
        assert!(super::f() == 3);
        let _ = t0.elapsed();
    }
}
"""
    root = write_tree(tmp_path, {"coordinator/ok.rs": text})
    assert rule_hits(lint.lint_tree(root), "C6-TIME") == []


def test_c6_marker_allows_with_reason(tmp_path):
    text = VIOLATING_TIME.replace(
        "    let now = Instant::now();",
        "    // lint: time-ok (host-side telemetry only, never drives scheduling)\n"
        "    let now = Instant::now();",
    ).replace(
        "use std::time::{Duration, SystemTime};",
        "// lint: time-ok (host-side telemetry only, never drives scheduling)\n"
        "use std::time::{Duration, SystemTime};",
    ).replace(
        "pub fn age(epoch: SystemTime) -> u64 {",
        "// lint: time-ok (host-side telemetry only, never drives scheduling)\n"
        "pub fn age(epoch: SystemTime) -> u64 {",
    )
    root = write_tree(tmp_path, {"coordinator/remote/timey.rs": text})
    assert rule_hits(lint.lint_tree(root), "C6-TIME") == []


def test_c6_marker_without_reason_is_a_finding(tmp_path):
    text = "let _ = Instant::now(); // lint: time-ok ()\n"
    root = write_tree(tmp_path, {"telemetry/t.rs": "pub fn f() {\n    " + text + "}\n"})
    hits = rule_hits(lint.lint_tree(root), "C6-TIME")
    assert len(hits) == 2
    assert any("non-empty reason" in h.message for h in hits)


# -------------------------------------------------------------------------
# Scanner scope tracking
# -------------------------------------------------------------------------

def test_return_position_impl_trait_does_not_break_blessed_sites(tmp_path):
    # Regression: `-> impl Iterator<...>` used to push a phantom
    # `impl Iterator` scope that swallowed the next brace, so a blessed
    # `GroupCharges::charge` following such a method lost its (impl, fn)
    # attribution and C2 fired on the central charging site itself.
    text = """\
use crate::energy::OpCounts;

pub struct GroupCharges;

impl GroupCharges {
    pub fn entries(&self) -> impl Iterator<Item = u32> {
        [1u32].into_iter()
    }

    pub fn charge(&self, ops: &mut OpCounts, n: u64) {
        for _ in 0..n {
            ops.mvm_ops += 1;
            ops.merge_elements += 1;
        }
    }
}
"""
    root = write_tree(tmp_path, {"coordinator/gc.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


def test_argument_position_impl_trait_does_not_shadow_scopes(tmp_path):
    text = """\
use crate::energy::OpCounts;

pub struct GroupCharges;

impl GroupCharges {
    pub fn charge(&self, sink: impl FnMut(u64), ops: &mut OpCounts) {
        ops.mvm_ops += 1;
    }
}
"""
    root = write_tree(tmp_path, {"coordinator/gc2.rs": text})
    assert rule_hits(lint.lint_tree(root), "C2-CHARGE") == []


# -------------------------------------------------------------------------
# Marker hygiene, CLI surface, self-check
# -------------------------------------------------------------------------

def test_unknown_marker_tag_is_flagged(tmp_path):
    root = write_tree(
        tmp_path, {"array/m.rs": "// lint: blessed-ok (made-up tag)\npub fn f() {}\n"}
    )
    hits = rule_hits(lint.lint_tree(root), "C0-MARKER")
    assert len(hits) == 1
    assert "blessed-ok" in hits[0].message


def test_cli_exit_codes_and_report(tmp_path, capsys):
    root = write_tree(tmp_path, {"coordinator/bad.rs": VIOLATING_SYNC})
    assert lint.main(["--root", str(root)]) == 1
    out = capsys.readouterr().out
    assert "rust/src/coordinator/bad.rs:1: C3-SYNC" in out

    root2 = write_tree(tmp_path / "clean", {"coordinator/good.rs": CONFORMING_SYNC})
    assert lint.main(["--root", str(root2)]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_missing_root_is_usage_error(tmp_path):
    assert lint.main(["--root", str(tmp_path / "nope")]) == 2


@pytest.mark.parametrize("rule_id", list(lint.RULES))
def test_explain_prints_contract_and_backing_suite(rule_id, capsys):
    assert lint.main(["--explain", rule_id]) == 0
    out = capsys.readouterr().out
    assert rule_id in out
    assert "Invariant:" in out
    # Every contract names the dynamic suite backing it.
    assert "Dynamic backing:" in out
    assert f"// lint: {lint.RULES[rule_id].tag}-ok" in out


def test_explain_all_and_unknown_rule(capsys):
    assert lint.main(["--explain", "all"]) == 0
    out = capsys.readouterr().out
    for rule_id in lint.RULES:
        assert rule_id in out
    assert lint.main(["--explain", "C9-NOPE"]) == 2


def test_list_names_every_rule(capsys):
    assert lint.main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule_id in lint.RULES:
        assert rule_id in out


def test_committed_tree_is_lint_clean():
    findings = lint.lint_tree(REPO_ROOT)
    assert findings == [], "committed tree has lint findings:\n" + "\n".join(
        repr(f) for f in findings
    )
