"""Dimension-packing kernel (SpecPCM §III-B) vs oracle + algebraic properties."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pack_dims, ref
from compile.kernels.pack import packed_len, padded_packed_len
from compile.kernels.imc_mvm import ARRAY_DIM


def rand_hv(rng, b, d):
    return rng.choice([-1.0, 1.0], size=(b, d)).astype(np.float32)


class TestPackedLen:
    @pytest.mark.parametrize(
        "d,n,expect",
        [(2048, 1, 2048), (2048, 2, 1024), (2048, 3, 683), (8192, 3, 2731)],
    )
    def test_packed_len(self, d, n, expect):
        assert packed_len(d, n) == expect

    @pytest.mark.parametrize(
        "d,n,expect",
        [(2048, 3, 768), (8192, 3, 2816), (512, 3, 256), (1024, 3, 384), (4096, 3, 1408)],
    )
    def test_padded_is_tile_multiple(self, d, n, expect):
        p = padded_packed_len(d, n)
        assert p == expect and p % ARRAY_DIM == 0


class TestPackKernel:
    @pytest.mark.parametrize("n", [1, 2, 3])
    @pytest.mark.parametrize("d", [512, 2048, 8192])
    def test_matches_oracle(self, n, d):
        rng = np.random.default_rng(d + n)
        hv = rand_hv(rng, 64, d)
        out = np.asarray(pack_dims(jnp.array(hv), n))
        orc = np.asarray(ref.pack_dims(jnp.array(hv), n))
        np.testing.assert_array_equal(out, orc)

    def test_values_bounded_by_n(self):
        rng = np.random.default_rng(0)
        hv = rand_hv(rng, 64, 2048)
        out = np.asarray(pack_dims(jnp.array(hv), 3))
        assert np.abs(out).max() <= 3.0

    def test_parity_in_full_groups(self):
        """A full group of n +/-1 values sums to a value with parity n."""
        rng = np.random.default_rng(1)
        hv = rand_hv(rng, 64, 2046)  # 682 full groups of 3
        out = np.asarray(pack_dims(jnp.array(hv), 3))
        full = out[:, :682]
        assert np.all((full.astype(np.int64) - 3) % 2 == 0)

    def test_slc_identity(self):
        rng = np.random.default_rng(2)
        hv = rand_hv(rng, 64, 2048)
        out = np.asarray(pack_dims(jnp.array(hv), 1))
        np.testing.assert_array_equal(out, hv)

    def test_dot_product_preserved_for_identical_vectors(self):
        """<pack(h), pack(h)> relates to D: packing self-similarity stays
        maximal — the property that makes packed Hamming search work."""
        rng = np.random.default_rng(3)
        hv = rand_hv(rng, 8, 2048)
        p = np.asarray(pack_dims(jnp.array(hv), 3))
        # sum of squares of group sums >= D/n lower bound isn't tight;
        # instead check <pack(a),pack(b)> ordering follows <a,b> ordering
        a, b, c = hv[0], hv[1], hv[2]
        mixed = np.where(rng.random(2048) < 0.9, a, b).astype(np.float32)  # near a
        pm = np.asarray(pack_dims(jnp.array(mixed[None, :]), 3))[0]
        pa, pb = p[0], p[1]
        assert pm @ pa > pm @ pb


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 4),
    d=st.integers(1, 4096),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_pack_matches_oracle_any_d(n, d, seed):
    """Arbitrary (non-multiple) D: padding must keep the adjacent-sum exact."""
    rng = np.random.default_rng(seed)
    hv = rand_hv(rng, 8, d)
    out = np.asarray(pack_dims(jnp.array(hv), n))
    orc = np.asarray(ref.pack_dims(jnp.array(hv), n))
    np.testing.assert_array_equal(out, orc)
    # manual adjacent-sum check on the unpadded prefix
    full_groups = d // n
    if full_groups:
        manual = hv[:, : full_groups * n].reshape(8, full_groups, n).sum(-1)
        np.testing.assert_array_equal(out[:, :full_groups], manual)
