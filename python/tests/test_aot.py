"""AOT path: HLO-text lowering works and the manifest matches the graphs.

Full-size artifact generation happens in `make artifacts`; here we lower a
small representative variant in-process (fast) and validate the HLO text +
manifest plumbing, plus check prebuilt artifacts when they exist.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels.pack import padded_packed_len

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_mvm_lowers_to_hlo_text(self):
        text = aot.to_hlo_text(
            jax.jit(model.mvm_scores).lower(
                jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((128, 128), jnp.float32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
                jax.ShapeDtypeStruct((1, 1), jnp.float32),
            )
        )
        assert text.startswith("HloModule")
        assert "f32[64,128]" in text

    def test_enc_pack_lowers_to_hlo_text(self):
        from functools import partial

        text = aot.to_hlo_text(
            jax.jit(partial(model.encode_pack, n=3)).lower(
                jax.ShapeDtypeStruct((8, 32), jnp.int32),
                jax.ShapeDtypeStruct((32, 384), jnp.float32),
                jax.ShapeDtypeStruct((16, 384), jnp.float32),
            )
        )
        assert text.startswith("HloModule")

    def test_mvm_variant_widths_cover_enc_variants(self):
        widths = set(aot.mvm_variants())
        for d, n in aot.ENC_VARIANTS:
            assert padded_packed_len(d, n) in widths


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists_and_parses(self, manifest):
        for art in manifest["artifacts"]:
            path = os.path.join(ART_DIR, art["file"])
            assert os.path.exists(path), art["name"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), art["name"]

    def test_manifest_covers_all_variants(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for d, n in aot.ENC_VARIANTS:
            assert f"enc_pack_d{d}_n{n}" in names
        for c in aot.mvm_variants():
            assert f"mvm_c{c}" in names

    def test_manifest_shapes_consistent(self, manifest):
        for art in manifest["artifacts"]:
            if art["kind"] == "enc_pack":
                p = art["params"]
                assert p["packed"] == padded_packed_len(p["d"], p["n"])
                assert art["outputs"][0]["shape"] == [p["batch"], p["packed"]]
            elif art["kind"] == "mvm":
                p = art["params"]
                assert art["outputs"][0]["shape"] == [p["batch"], p["rows"]]
