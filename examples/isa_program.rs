//! ISA walkthrough (paper §III-F, Table S2): build a STORE_HV / READ_HV /
//! MVM_COMPUTE program programmatically, inspect its binary encoding and
//! assembler text, execute it on simulated banks, and show how the
//! instruction fields (MLC_bits, write_cycles, ADC_bits) steer the
//! hardware.
//!
//! Run: `cargo run --release --example isa_program`

use specpcm::array::ARRAY_DIM;
use specpcm::device::Material;
use specpcm::isa::{decode, encode, Executor, Instruction, Program};
use specpcm::util::error::Result;

fn main() -> Result<()> {
    // A packed HV segment (values in the MLC3 alphabet).
    let segment: Vec<f32> = (0..ARRAY_DIM)
        .map(|i| ((i % 7) as i64 - 3) as f32)
        .collect();

    let mut prog = Program::new();
    // Program the segment into array 2, row 9, with 3 write-verify cycles.
    prog.push(Instruction::StoreHv {
        buf: 0,
        arr_idx: 2,
        col_addr: 0,
        row_addr: 9,
        mlc_bits: 3,
        write_cycles: 3,
    });
    // Read it back through the sense amps.
    prog.push(Instruction::ReadHv {
        buf: 1,
        data_size: ARRAY_DIM as u16,
        arr_idx: 2,
        col_addr: 0,
        row_addr: 9,
        mlc_bits: 3,
    });
    // In-memory dot product of the same segment against all 128 rows.
    prog.push(Instruction::MvmCompute {
        buf: 0,
        arr_idx: 2,
        row_addr: 0,
        num_activated_row: 128,
        adc_bits: 6,
        mlc_bits: 3,
    });
    prog.validate()?;

    println!("== assembler text ==\n{}\n", prog.disassemble());
    println!("== binary encoding ==");
    for inst in &prog.instructions {
        let word = encode(inst);
        println!("  {:#018x}  {}", word, inst.mnemonic());
        assert_eq!(decode(word).unwrap(), *inst); // round-trip
    }

    let mut ex = Executor::new(4, Material::TiTe2Gst467, 7);
    ex.set_buffer(0, segment.clone());
    let res = ex.run(&prog)?;

    println!("\n== execution ==");
    println!(
        "  ops: {} MVM, {} row reads, {} program rounds, {} verify rounds",
        res.ops.mvm_ops, res.ops.row_reads, res.ops.program_rounds, res.ops.verify_rounds
    );
    let read = &res.row_reads[0];
    let err: f32 = read
        .iter()
        .zip(&segment)
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / ARRAY_DIM as f32;
    println!("  readback mean |error| after 3 write-verify cycles: {err:.4}");

    let scores = &res.mvm_scores[0];
    let best = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap();
    println!(
        "  MVM best row = {} (score {:.1}) — the row we programmed",
        best.0, best.1
    );
    assert_eq!(best.0, 9);

    // The same program round-trips through the assembler.
    let reparsed = Program::assemble(&prog.disassemble())?;
    assert_eq!(reparsed.instructions, prog.instructions);
    println!("\nassembler round-trip OK");
    Ok(())
}
