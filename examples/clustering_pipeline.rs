//! End-to-end clustering driver — the full-system validation workload
//! (DESIGN.md: "end-to-end driver that exercises the full system").
//!
//! Drives the complete SpecPCM stack on a PXD000561-like synthetic corpus:
//! synthetic spectra -> preprocessing -> HD encode+pack (PJRT encoder
//! artifact) -> PCM programming with write-verify noise -> analog IMC
//! pairwise distances (PJRT MVM artifact) -> complete-linkage merging ->
//! quality curve + energy/latency accounting, and compares quality against
//! the software baselines (falcon-like, msCRUSH-like, HyperSpec-like).
//!
//! Run: `cargo run --release --example clustering_pipeline [scale]`

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::{greedy_nn, hd_soft, levels_to_f32, lsh};
use specpcm::cluster::quality::{clustered_at_incorrect, evaluate};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, HdFrontend};
use specpcm::hd;
use specpcm::ms::{bucket_by_precursor, ClusteringDataset, Spectrum};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.4);

    let cfg = SpecPcmConfig {
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let ds = ClusteringDataset::pxd000561_like(cfg.seed, scale);
    println!(
        "dataset: {} -> {} synthetic spectra ({} ground-truth peptides; stands in for {} real spectra)",
        ds.name,
        ds.len(),
        ds.n_peptides,
        ds.paper_spectra
    );

    let backend = BackendDispatcher::from_config(&cfg);
    println!("execution path: {} backend", backend.primary_name());

    // ---- SpecPCM -----------------------------------------------------------
    let t0 = std::time::Instant::now();
    let out = ClusteringPipeline::new(cfg.clone()).run(&ds, &backend)?;
    let host_s = t0.elapsed().as_secs_f64();

    println!("\n== SpecPCM (simulated accelerator) ==");
    println!("  buckets processed:      {}", out.n_buckets);
    println!("  array MVM ops:          {}", out.ops.mvm_ops);
    println!("  programming rounds:     {}", out.ops.program_rounds);
    println!("  simulated energy:       {:.4} mJ", out.report.total_j() * 1e3);
    println!(
        "  simulated latency:      {:.4} ms (overlapped)",
        out.report.overlapped_latency_s() * 1e3
    );
    println!("  host wall time:         {host_s:.2} s");
    for (stage, t, f) in out.wall.breakdown() {
        println!("    {stage:<18} {t:>8.3} s  {:>5.1}%", f * 100.0);
    }

    // ---- Software baselines on the same spectra ------------------------------
    let truth: Vec<u32> = ds
        .spectra
        .iter()
        .map(|s| s.peptide_id.unwrap_or(u32::MAX))
        .collect();
    let fe = HdFrontend::new(&cfg);
    let buckets = bucket_by_precursor(&ds.spectra, cfg.bucket_width);

    // Shared preprocessed vectors.
    let all: Vec<&Spectrum> = ds.spectra.iter().collect();
    let levels = fe.levels_of(&all);
    let floats: Vec<Vec<f32>> = levels.iter().map(|l| levels_to_f32(l)).collect();

    let run_baseline = |labels: Vec<usize>| evaluate(&labels, &truth, 0.0);

    // falcon-like greedy NN per bucket.
    let t0 = std::time::Instant::now();
    let mut falcon_labels = vec![usize::MAX; ds.len()];
    let mut next = 0usize;
    for members in buckets.values() {
        let vecs: Vec<Vec<f32>> = members.iter().map(|&i| floats[i].clone()).collect();
        let local = greedy_nn::cluster(&vecs, 0.75);
        for (li, &gi) in members.iter().enumerate() {
            falcon_labels[gi] = next + local[li];
        }
        next += members.len();
    }
    let falcon_q = run_baseline(falcon_labels);
    let falcon_s = t0.elapsed().as_secs_f64();

    // msCRUSH-like LSH per bucket.
    let t0 = std::time::Instant::now();
    let mut lsh_labels = vec![usize::MAX; ds.len()];
    let mut next = 0usize;
    for members in buckets.values() {
        let vecs: Vec<Vec<f32>> = members.iter().map(|&i| floats[i].clone()).collect();
        let local = lsh::cluster(&vecs, 6, 12, 0.7, cfg.seed);
        for (li, &gi) in members.iter().enumerate() {
            lsh_labels[gi] = next + local[li];
        }
        next += members.len();
    }
    let lsh_q = run_baseline(lsh_labels);
    let lsh_s = t0.elapsed().as_secs_f64();

    // HyperSpec-like exact binary HD per bucket.
    let t0 = std::time::Instant::now();
    let hvs: Vec<hd::Hv> = levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let mut hs_best = 0.0f64;
    {
        // sweep the same thresholds as SpecPCM
        for &t in &cfg.threshold_sweep {
            let mut labels = vec![usize::MAX; ds.len()];
            let mut next = 0usize;
            for members in buckets.values() {
                let local_hvs: Vec<hd::Hv> =
                    members.iter().map(|&i| hvs[i].clone()).collect();
                let dend = hd_soft::cluster(&local_hvs, t);
                let local = dend.cut(t);
                for (li, &gi) in members.iter().enumerate() {
                    labels[gi] = next + local[li];
                }
                next += members.len();
            }
            let q = evaluate(&labels, &truth, t);
            if q.incorrect_ratio <= 0.015 && q.clustered_ratio > hs_best {
                hs_best = q.clustered_ratio;
            }
        }
    }
    let hs_s = t0.elapsed().as_secs_f64();

    let spec_best = clustered_at_incorrect(&out.curve, 0.015);
    let rows = vec![
        vec![
            "falcon-like (greedy NN)".into(),
            format!("{:.3}", falcon_q.clustered_ratio),
            format!("{:.4}", falcon_q.incorrect_ratio),
            format!("{falcon_s:.2}s"),
        ],
        vec![
            "msCRUSH-like (LSH)".into(),
            format!("{:.3}", lsh_q.clustered_ratio),
            format!("{:.4}", lsh_q.incorrect_ratio),
            format!("{lsh_s:.2}s"),
        ],
        vec![
            "HyperSpec-like (exact HD)".into(),
            format!("{hs_best:.3} @<=1.5% incorrect"),
            "-".into(),
            format!("{hs_s:.2}s"),
        ],
        vec![
            "SpecPCM (MLC3 + noise)".into(),
            format!("{spec_best:.3} @<=1.5% incorrect"),
            "-".into(),
            format!("{host_s:.2}s host"),
        ],
    ];
    println!(
        "\n{}",
        render_table(
            "clustering quality (synthetic PXD000561-like)",
            &["tool", "clustered ratio", "incorrect ratio", "host time"],
            &rows
        )
    );
    println!(
        "expected shape (paper Fig. 9): SpecPCM ~= HyperSpec > falcon > msCRUSH; \
         MLC packing costs <~1% clustered ratio."
    );
    Ok(())
}
