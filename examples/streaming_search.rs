//! Streaming DB search through the persistent [`SearchEngine`] (the paper's
//! Table 3 serving shape): the reference library is encoded and programmed
//! into the PCM banks exactly **once**, then query batches stream against
//! the stored conductances. Contrast with re-running `SearchPipeline::run`,
//! which would re-pay the one-time programming cost on every invocation.
//!
//! The last section shows the shard layer: the same library on engines too
//! small to hold it, split by a [`ShardedSearchEngine`] and served with
//! concurrent per-shard fan-out — bit-identical to one big-enough engine.
//!
//! Run: `cargo run --release --example streaming_search [n_batches]`

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{SearchEngine, SearchPipeline, ShardedSearchEngine};
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    let n_batches: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    let cfg = SpecPcmConfig {
        hd_dim: 2048, // keep the example snappy; the paper default is 8192
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::iprg2012_like(cfg.seed, 0.25);
    let backend = BackendDispatcher::from_config(&cfg);
    let fdr = cfg.fdr;

    // ---- program once -------------------------------------------------------
    let engine = SearchEngine::program(cfg.clone(), &ds, &backend)?;
    let prog = *engine.program_report();
    println!(
        "library: {} targets + {} decoys -> {} rows programmed once \
         ({} program rounds, {:.4} mJ, {:.4} ms)",
        ds.library.len(),
        ds.decoys.len(),
        engine.n_refs(),
        engine.program_ops().program_rounds,
        prog.total_j() * 1e3,
        prog.total_latency_s() * 1e3
    );

    // ---- stream query batches ----------------------------------------------
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let outcomes = engine.serve_chunked(&queries, n_batches, &backend)?;
    let rows: Vec<Vec<String>> = outcomes
        .iter()
        .enumerate()
        .map(|(bi, out)| {
            vec![
                format!("{bi}"),
                format!("{}", out.pairs.len()),
                format!("{}", out.ops.mvm_ops),
                format!("{:.4}", out.report.total_j() * 1e3),
                format!("{:.4}", out.report.overlapped_latency_s() * 1e3),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            "marginal per-batch cost (no programming re-paid)",
            &["batch", "queries", "MVM ops", "energy mJ", "latency ms"],
            &rows
        )
    );

    let cost = engine.serving_cost(&outcomes);
    println!(
        "energy: one-time {:.4} mJ + marginal {:.4} mJ -> amortized {:.4} mJ/batch",
        cost.one_time_j * 1e3,
        cost.marginal_j * 1e3,
        cost.amortized_j_per_batch() * 1e3
    );

    // ---- identical to the one-shot pipeline --------------------------------
    let out = engine.finalize(&queries, &outcomes)?;
    println!(
        "identified {}/{} queries at {:.0}% FDR ({} correct)",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct
    );

    let one_shot = SearchPipeline::new(cfg.clone()).run(&ds, &backend)?;
    assert_eq!(out.pairs, one_shot.pairs);
    assert_eq!(out.fdr.accepted, one_shot.fdr.accepted);
    assert_eq!(out.ops.mvm_ops, one_shot.ops.mvm_ops);
    println!(
        "check OK: {n_batches}-batch serving is bit-identical to the one-shot \
         pipeline, with the library programmed once instead of twice."
    );

    // ---- shard layer: the library on engines too small to hold it ----------
    // 12 banks at D=2048 n=3 hold 256 reference rows; the 400-row library
    // overflows one engine, so the shard layer auto-splits it in two and
    // fans every batch across both shards on scoped threads.
    let small = SpecPcmConfig {
        num_banks: 12,
        ..cfg.clone()
    };
    assert!(SearchEngine::program(small.clone(), &ds, &backend).is_err());
    let sharded = ShardedSearchEngine::program(small, &ds, &backend, 0)?;
    println!(
        "sharded: {} rows across {} shards x 12 banks, rows/shard {:?}",
        sharded.n_refs(),
        sharded.n_shards(),
        sharded
            .plan()
            .ranges()
            .iter()
            .map(|r| r.len())
            .collect::<Vec<_>>()
    );
    let sharded_out = {
        let outcomes = sharded.serve_chunked(&queries, n_batches, &backend)?;
        sharded.finalize(&queries, &outcomes)?
    };

    // The monolithic equivalent owns the union pool: 2 x 12 = 24 banks.
    let union = SpecPcmConfig {
        num_banks: sharded.total_banks(),
        ..cfg
    };
    let mono = SearchEngine::program(union, &ds, &backend)?;
    let mono_batch = mono.search_batch(&queries, &backend)?;
    let mono_out = mono.finalize(&queries, &[mono_batch])?;
    assert_eq!(sharded_out.pairs, mono_out.pairs);
    assert_eq!(sharded_out.fdr.accepted, mono_out.fdr.accepted);
    assert_eq!(sharded_out.ops, mono_out.ops);
    println!(
        "shard check OK: {} shards of 12 banks serve bit-identically to one \
         {}-bank engine — same results, same total simulated ASIC work.",
        sharded.n_shards(),
        sharded.total_banks()
    );
    Ok(())
}
