//! Design-space exploration (paper §IV-B "Accuracy and efficiency
//! trade-offs"): sweeps the ISA-controlled knobs — bits per cell, ADC
//! precision, write-verify cycles — on a fixed search workload and prints
//! the quality/energy/latency matrix the instruction set lets software
//! navigate.
//!
//! Run: `cargo run --release --example design_space`

use specpcm::backend::BackendDispatcher;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::SearchPipeline;
use specpcm::ms::SearchDataset;
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

fn main() -> Result<()> {
    let base = SpecPcmConfig {
        hd_dim: 2048, // keep the sweep fast; shapes match D=8192
        ..SpecPcmConfig::paper_search()
    };
    let ds = SearchDataset::iprg2012_like(base.seed, 0.3);
    println!(
        "workload: {} queries vs {} refs (+decoys), D={}, FDR {:.0}%\n",
        ds.queries.len(),
        ds.library.len(),
        base.hd_dim,
        base.fdr * 100.0
    );
    let backend = BackendDispatcher::from_config(&base);

    let mut rows = Vec::new();
    let mut run = |label: String, cfg: SpecPcmConfig| -> Result<()> {
        let out = SearchPipeline::new(cfg).run(&ds, &backend)?;
        rows.push(vec![
            label,
            format!("{}", out.identified),
            format!("{}", out.correct),
            format!("{:.4}", out.report.total_j() * 1e3),
            format!("{:.4}", out.report.overlapped_latency_s() * 1e3),
        ]);
        Ok(())
    };

    // (1) bits per cell (§IV-B (1)): SLC / MLC2 / MLC3.
    for mlc in [1u8, 2, 3] {
        run(
            format!("MLC{mlc} (n={mlc})"),
            SpecPcmConfig { mlc_bits: mlc, ..base.clone() },
        )?;
    }
    // (2) ADC resolution (§IV-B (4)): 6 -> 1 bits.
    for adc in [6u32, 4, 3, 2, 1] {
        run(
            format!("ADC {adc}-bit"),
            SpecPcmConfig { adc_bits: adc, ..base.clone() },
        )?;
    }
    // (3) write-verify cycles (§IV-B (3)).
    for wv in [0u32, 1, 3, 6] {
        run(
            format!("write-verify x{wv}"),
            SpecPcmConfig { write_verify: wv, ..base.clone() },
        )?;
    }

    println!(
        "{}",
        render_table(
            "design space (fixed workload)",
            &["config", "identified", "correct", "energy mJ", "latency ms"],
            &rows
        )
    );
    println!(
        "expected shapes (paper Figs. 9/10, S3): identifications fall slowly\n\
         from SLC to MLC3; 4-bit ADC nearly matches 6-bit at ~4x less ADC\n\
         energy; more write-verify raises quality and programming latency."
    );
    Ok(())
}
