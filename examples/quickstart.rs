//! Quickstart: the whole SpecPCM stack in ~60 lines.
//!
//! Generates a small synthetic MS workload, runs both paper pipelines
//! (spectral clustering + DB search) through the analog-IMC simulator, and
//! prints quality plus the simulated energy/latency of the accelerator.
//! MVM work executes on the configured backend (bank-sharded parallel by
//! default; `pjrt` when the feature + artifacts are available) — all
//! bit-identical to the rust reference path.
//!
//! Run: `cargo run --release --example quickstart`

use specpcm::backend::BackendDispatcher;
use specpcm::cluster::quality::clustered_at_incorrect;
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{ClusteringPipeline, SearchPipeline};
use specpcm::ms::{ClusteringDataset, SearchDataset};
use specpcm::util::error::Result;

fn main() -> Result<()> {

    // --- Clustering (paper Fig. 1; defaults from §IV-A) -------------------
    let cfg = SpecPcmConfig {
        bucket_width: 50.0,
        ..SpecPcmConfig::paper_clustering()
    };
    let backend = BackendDispatcher::from_config(&cfg);
    println!("MVM backend: {}", backend.primary_name());

    let ds = ClusteringDataset::pxd001468_like(cfg.seed, 0.2);
    println!("\n[clustering] {} spectra ({})", ds.len(), ds.name);
    let out = ClusteringPipeline::new(cfg).run(&ds, &backend)?;
    println!(
        "  clustered {:.1}% of spectra at <=1.5% incorrect ratio",
        100.0 * clustered_at_incorrect(&out.curve, 0.015)
    );
    println!(
        "  simulated accelerator: {:.3} mJ, {:.3} ms ({} array MVMs)",
        out.report.total_j() * 1e3,
        out.report.overlapped_latency_s() * 1e3,
        out.ops.mvm_ops
    );

    // --- DB search (paper Fig. 2) -----------------------------------------
    let cfg = SpecPcmConfig {
        hd_dim: 2048, // keep the quickstart snappy; the paper default is 8192
        ..SpecPcmConfig::paper_search()
    };
    let fdr = cfg.fdr;
    let ds = SearchDataset::iprg2012_like(cfg.seed, 0.15);
    println!(
        "\n[db search] {} queries vs {} refs + {} decoys ({})",
        ds.queries.len(),
        ds.library.len(),
        ds.decoys.len(),
        ds.name
    );
    let out = SearchPipeline::new(cfg).run(&ds, &backend)?;
    println!(
        "  identified {}/{} queries at {:.0}% FDR ({} ground-truth correct)",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct
    );
    println!(
        "  simulated accelerator: {:.3} mJ, {:.3} ms",
        out.report.total_j() * 1e3,
        out.report.overlapped_latency_s() * 1e3
    );

    Ok(())
}
