//! End-to-end DB-search driver (paper Fig. 2): open-modification search of
//! a HEK293-like synthetic query set against a target+decoy library, with
//! quality compared against ANN-SoLo-like (exact cosine) and HyperOMS-like
//! (exact binary HD) software baselines at the same 1% FDR.
//!
//! Run: `cargo run --release --example db_search [scale]`

use specpcm::backend::BackendDispatcher;
use specpcm::baselines::{exact, hd_soft, levels_to_f32};
use specpcm::config::SpecPcmConfig;
use specpcm::coordinator::{HdFrontend, SearchPipeline};
use specpcm::hd;
use specpcm::ms::{SearchDataset, Spectrum};
use specpcm::search::fdr_filter;
use specpcm::telemetry::render_table;
use specpcm::util::error::Result;

/// Run a software baseline: score all queries vs all refs (targets then
/// decoys), pick best target/decoy per query, FDR-filter, count correct.
fn baseline_identify(
    scores: impl Fn(usize) -> Vec<f32>, // per-query score row over all refs
    ds: &SearchDataset,
    fdr: f64,
) -> (usize, usize) {
    let nt = ds.library.len();
    let mut pairs = Vec::with_capacity(ds.queries.len());
    let mut matched: Vec<Option<u32>> = Vec::with_capacity(ds.queries.len());
    for qi in 0..ds.queries.len() {
        let row = scores(qi);
        let (ti, ts) = row[..nt]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let dsc = row[nt..].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        pairs.push((*ts, dsc));
        matched.push(ds.library[ti].peptide_id);
    }
    let r = fdr_filter(&pairs, fdr);
    let correct = r
        .accepted
        .iter()
        .filter(|&&qi| matched[qi].is_some() && matched[qi] == ds.queries[qi].peptide_id)
        .count();
    (r.accepted.len(), correct)
}

fn main() -> Result<()> {
    // Default scale 0.18 keeps the library inside the paper config's bank
    // capacity (D=8192 n=3 -> 22 segments -> 5 groups x 128 = 640 slots;
    // 0.18 -> 288 targets + 288 decoys = 576 rows). The engine enforces
    // this: a larger scale fails with a CapacityError telling you to raise
    // num_banks.
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.18);

    let cfg = SpecPcmConfig::paper_search();
    let ds = SearchDataset::hek293_like(cfg.seed, scale);
    println!(
        "dataset: {} -> {} queries vs {} targets + {} decoys (stands in for {} queries x {} refs)",
        ds.name,
        ds.queries.len(),
        ds.library.len(),
        ds.decoys.len(),
        ds.paper_queries,
        ds.paper_library
    );

    let backend = BackendDispatcher::from_config(&cfg);
    println!("execution path: {} backend (D=8192, MLC3)", backend.primary_name());

    // ---- SpecPCM ------------------------------------------------------------
    let fdr = cfg.fdr;
    let t0 = std::time::Instant::now();
    let out = SearchPipeline::new(cfg.clone()).run(&ds, &backend)?;
    let host_s = t0.elapsed().as_secs_f64();
    println!("\n== SpecPCM (simulated accelerator) ==");
    println!(
        "  identified {}/{} at {:.0}% FDR ({} correct, {} distinct peptides)",
        out.identified,
        out.total_queries,
        fdr * 100.0,
        out.correct,
        out.identified_peptides.len()
    );
    println!("  array MVMs: {}   program rounds: {}", out.ops.mvm_ops, out.ops.program_rounds);
    println!(
        "  simulated: {:.4} mJ, {:.4} ms (overlapped)",
        out.report.total_j() * 1e3,
        out.report.overlapped_latency_s() * 1e3
    );
    for (stage, t, f) in out.wall.breakdown() {
        println!("    {stage:<20} {t:>8.3} s  {:>5.1}%", f * 100.0);
    }

    // ---- Baselines ------------------------------------------------------------
    let fe = HdFrontend::new(&cfg);
    let all_refs: Vec<&Spectrum> = ds.library.iter().chain(ds.decoys.iter()).collect();
    let ref_levels = fe.levels_of(&all_refs);
    let queries: Vec<&Spectrum> = ds.queries.iter().collect();
    let q_levels = fe.levels_of(&queries);

    // ANN-SoLo-like: exact cosine with the shifted-dot-product open-mod
    // alignment (see baselines::exact::search_scores_shifted).
    let t0 = std::time::Instant::now();
    let ref_floats: Vec<Vec<f32>> = ref_levels.iter().map(|l| levels_to_f32(l)).collect();
    let bin_w = (1900.0 - 100.0) / 512.0;
    let shifts: Vec<i64> = specpcm::ms::synth::PTM_SHIFTS
        .iter()
        .map(|&d| (d / bin_w).round() as i64)
        .collect();
    let (ann_id, ann_ok) = baseline_identify(
        |qi| exact::search_scores_shifted(&levels_to_f32(&q_levels[qi]), &ref_floats, &shifts),
        &ds,
        fdr,
    );
    let ann_s = t0.elapsed().as_secs_f64();

    // HyperOMS-like exact binary HD.
    let t0 = std::time::Instant::now();
    let ref_hvs: Vec<hd::Hv> = ref_levels.iter().map(|l| hd::encode(l, &fe.im)).collect();
    let ref_bits = hd_soft::pack_refs(&ref_hvs);
    let (oms_id, oms_ok) = baseline_identify(
        |qi| hd_soft::search_scores(&hd::encode(&q_levels[qi], &fe.im), &ref_bits),
        &ds,
        fdr,
    );
    let oms_s = t0.elapsed().as_secs_f64();

    let rows = vec![
        vec![
            "ANN-SoLo-like (shifted cosine)".into(),
            format!("{ann_id}"),
            format!("{ann_ok}"),
            format!("{ann_s:.2}s"),
        ],
        vec![
            "HyperOMS-like (exact HD)".into(),
            format!("{oms_id}"),
            format!("{oms_ok}"),
            format!("{oms_s:.2}s"),
        ],
        vec![
            "SpecPCM (MLC3 + PCM noise)".into(),
            format!("{}", out.identified),
            format!("{}", out.correct),
            format!("{host_s:.2}s host"),
        ],
    ];
    println!(
        "\n{}",
        render_table(
            "identifications at 1% FDR (synthetic HEK293-like)",
            &["tool", "identified", "correct", "host time"],
            &rows
        )
    );
    println!(
        "expected shape (paper Fig. 10): ANN-SoLo highest, SpecPCM within a few\n\
         percent of HyperOMS (the MLC/ADC/noise cost), all well above chance."
    );
    Ok(())
}
